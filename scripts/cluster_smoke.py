#!/usr/bin/env python
"""Cluster smoke: 3 ``tasm_serve.py`` nodes behind one ``tasm_router.py``,
two concurrent client PROCESSES, and a node killed mid-workload.  Asserts
the distributed-serving contract end to end, across real process
boundaries:

- both clients' results are bit-identical to an in-process ``execute()``
  of the same scans on an identically-built local store;
- with ``--replication 2``, SIGKILLing one node while a client is
  mid-workload loses NO reads — every remaining iteration still returns
  bit-identical results (the router fails reads over to the surviving
  replica);
- the router reports the killed node down, and SIGTERM shuts router and
  nodes down cleanly (exit 0, socket files gone);
- self-healing: after a foreground retile, a fresh disk-backed node joins
  (``tasm_router.py --join-node``), ``--repair node=<dead>`` restores
  K=2 — with the destination SIGKILLed mid-copy and restarted, the
  retried repair resumes from staged chunks, a client iterating
  throughout loses zero reads, every wave stays bit-identical, and the
  rebuilt replica serves the post-retile epoch (never the stale
  generation).

Exits non-zero on any violation — this is the CI cluster-smoke step::

    python scripts/cluster_smoke.py

``--faults`` additionally wires the fresh node through the byte-level
fault proxy (``tests/faults.py``) — the repair stream gets a mid-stream
disconnect, a torn frame, and slow-link delays injected, and must still
converge (the CI chaos-smoke step).

The script doubles as its own client: ``cluster_smoke.py --client SOCK
OUT [ITERS SLEEP]`` connects to the router, runs the canonical workload
``ITERS`` times (sleeping ``SLEEP`` seconds between iterations), and
writes results to ``OUT.npz`` + ``OUT.json`` for the parent to compare.
"""
from __future__ import annotations

import json
import os
import pathlib
import signal
import socket
import subprocess
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.codec.encode import EncoderConfig  # noqa: E402
from repro.core import (ClusterClient, NoTilingPolicy,  # noqa: E402
                        VideoStore)
from repro.data.video_gen import generate, sparse_spec  # noqa: E402

ENC = EncoderConfig(gop=16, qp=8)
N_FRAMES, H, W = 32, 96, 160
VIDEOS = ["cam0", "cam1", "cam2", "cam3"]
#: the canonical workload: per-video windows over two labels
WORKLOAD = [(v, label, rng) for v in VIDEOS
            for label, rng in (("car", (0, 32)), ("person", (8, 24)))]


def corpus():
    return {v: generate(sparse_spec(seed=i, n_frames=N_FRAMES, height=H,
                                    width=W))
            for i, v in enumerate(VIDEOS)}


def run_workload(store):
    return [store.scan(v).labels(label).frames(*rng).execute()
            for v, label, rng in WORKLOAD]


# --------------------------------------------------------------- client
def client_main(sock_path: str, out: str, iters: str = "1",
                sleep_s: str = "0") -> int:
    with ClusterClient(sock_path) as cli:
        waves = []
        for _ in range(int(iters)):
            waves.append(run_workload(cli))
            time.sleep(float(sleep_s))
    arrays, meta = {}, []
    for w, results in enumerate(waves):
        wave_meta = []
        for i, r in enumerate(results):
            regs = []
            for j, (f, box, px) in enumerate(r.regions):
                arrays[f"px_{w}_{i}_{j}"] = px
                regs.append([f, list(box)])
            wave_meta.append(regs)
        meta.append(wave_meta)
    np.savez(out + ".npz", **arrays)
    pathlib.Path(out + ".json").write_text(json.dumps(meta))
    return 0


def load_client(out: str):
    meta = json.loads(pathlib.Path(out + ".json").read_text())
    npz = np.load(out + ".npz")
    return [[[(f, tuple(box), npz[f"px_{w}_{i}_{j}"])
              for j, (f, box) in enumerate(regs)]
             for i, regs in enumerate(wave)]
            for w, wave in enumerate(meta)]


def assert_same_regions(a, b, where: str) -> None:
    assert len(a) == len(b), f"{where}: {len(a)} vs {len(b)} regions"
    for ra, rb in zip(a, b):
        assert ra[:-1] == rb[:-1], f"{where}: region keys diverge"
        if not np.array_equal(ra[-1], rb[-1]):
            raise AssertionError(f"{where}: pixels not bit-identical at "
                                 f"frame {ra[0]}")


def assert_wave_matches(wave, reference, where: str) -> None:
    assert len(wave) == len(reference), f"{where}: workload length"
    for q, (got, ref) in enumerate(zip(wave, reference)):
        assert_same_regions(ref.regions, got, f"{where} query {q}")


# --------------------------------------------------------------- parent
def wait_for_socket(path: str, proc, timeout: float = 60.0) -> None:
    deadline = time.time() + timeout
    while time.time() < deadline:
        if proc.poll() is not None:
            raise RuntimeError(f"server died early (rc={proc.returncode})")
        if os.path.exists(path):
            s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            try:
                s.connect(path)
                return
            except OSError:
                pass
            finally:
                s.close()
        time.sleep(0.05)
    raise RuntimeError(f"socket {path} never came up")


def main() -> int:
    if len(sys.argv) > 1 and sys.argv[1] == "--client":
        return client_main(*sys.argv[2:])
    faults_mode = "--faults" in sys.argv[1:]

    tmp = tempfile.mkdtemp(prefix="tasm_cluster_smoke_")
    here = os.path.dirname(os.path.abspath(__file__))
    node_socks = [os.path.join(tmp, f"n{i}.sock") for i in range(3)]
    router_sock = os.path.join(tmp, "router.sock")
    nodes = [subprocess.Popen(
        [sys.executable, os.path.join(here, "tasm_serve.py"),
         "--socket", sock]) for sock in node_socks]
    router = None
    proxy = None
    try:
        for sock, proc in zip(node_socks, nodes):
            wait_for_socket(sock, proc)
        router = subprocess.Popen(
            [sys.executable, os.path.join(here, "tasm_router.py"),
             "--socket", router_sock, "--replication", "2",
             "--placement", os.path.join(tmp, "placement.json"),
             "--timeout", "15", "--health-interval", "0.5"]
            + [a for i, sock in enumerate(node_socks)
               for a in ("--node", f"n{i}={sock}")])
        wait_for_socket(router_sock, router)
        videos = corpus()

        # seed the cluster through the router, and build the in-process
        # reference store identically (encode is deterministic)
        local = VideoStore()
        with ClusterClient(router_sock) as seed:
            for name, (frames, dets) in videos.items():
                for store in (seed, local):
                    store.add_video(name, encoder=ENC,
                                    policy=NoTilingPolicy())
                    store.ingest(name, frames)
                    store.add_detections(name,
                                         {f: d for f, d in enumerate(dets)})
            placement = seed.placement()["assignments"]
        reference = run_workload(local)  # local stays open: the
        # self-healing phase retiles both sides and re-derives it

        # two concurrent client processes over one router
        outs = [os.path.join(tmp, f"client{i}") for i in (1, 2)]
        clients = [subprocess.Popen(
            [sys.executable, os.path.abspath(__file__), "--client",
             router_sock, out]) for out in outs]
        rcs = [c.wait(timeout=300) for c in clients]
        assert rcs == [0, 0], f"client exit codes {rcs}"
        got = [load_client(out)[0] for out in outs]
        assert_wave_matches(got[0], reference, "client1 vs local")
        assert_wave_matches(got[1], reference, "client2 vs local")
        print(f"# two concurrent clients bit-identical to in-process "
              f"execute ({sum(len(r) for r in got[0])} regions)")

        # kill cam0's PRIMARY mid-workload: a third client iterates the
        # workload; with K=2 every video keeps a live replica, so every
        # wave — before, during, and after the kill — must stay
        # bit-identical
        victim = int(placement["cam0"][0][1:])  # "n2" -> index 2
        out3 = os.path.join(tmp, "client3")
        killer = subprocess.Popen(
            [sys.executable, os.path.abspath(__file__), "--client",
             router_sock, out3, "6", "0.2"])
        time.sleep(0.6)  # a couple of waves in
        nodes[victim].send_signal(signal.SIGKILL)
        nodes[victim].wait(timeout=30)
        rc = killer.wait(timeout=300)
        assert rc == 0, f"mid-kill client exit code {rc}"
        waves = load_client(out3)
        assert len(waves) == 6
        for w, wave in enumerate(waves):
            assert_wave_matches(wave, reference,
                                f"wave {w} (node n{victim} killed)")
        with ClusterClient(router_sock) as probe:
            health = probe.node_health()
            assert health[f"n{victim}"] is False, health
            assert sum(1 for ok in health.values() if ok) == 2, health
        print(f"# killed n{victim} mid-workload: 6/6 waves bit-identical, "
              f"router reports it down")

        # ---- self-healing: fresh node joins, repair restores K=2 ----
        # retile cam0 first so the rebuilt replica must prove it serves
        # the POST-retile generation, never the stale one
        from repro.core import uniform_layout
        with ClusterClient(router_sock) as adm:
            adm.retile("cam0", 0, uniform_layout(H, W, 2, 2))
        local.retile("cam0", 0, uniform_layout(H, W, 2, 2))
        reference = run_workload(local)
        local.close()

        n3_sock = os.path.join(tmp, "n3.sock")
        n3_root = os.path.join(tmp, "store-n3")  # disk-backed: staged
        # chunks must survive the destination SIGKILL below

        def start_n3():
            p = subprocess.Popen(
                [sys.executable, os.path.join(here, "tasm_serve.py"),
                 "--socket", n3_sock, "--store-root", n3_root])
            wait_for_socket(n3_sock, p)
            return p

        n3 = start_n3()
        nodes.append(n3)
        n3_addr = n3_sock
        if faults_mode:
            sys.path.insert(0, os.path.join(here, "..", "tests"))
            from faults import Fault, FaultProxy
            proxy = FaultProxy(n3_sock, faults=[
                Fault(cut_after=20000),                   # mid-stream cut
                Fault(corrupt_at=4000, direction="c2b"),  # torn frame
                Fault(delay_s=0.05), Fault(delay_s=0.05),  # slow link
            ])
            n3_addr = proxy.address
            print("# fault proxy armed in front of n3 "
                  "(cut, torn frame, delays)")

        def router_admin(*argv, check=True, timeout=300):
            rc = subprocess.call(
                [sys.executable, os.path.join(here, "tasm_router.py"),
                 "--socket", router_sock, *argv], timeout=timeout)
            if check:
                assert rc == 0, f"tasm_router.py {argv} exit code {rc}"
            return rc

        router_admin("--join-node", f"n3={n3_addr}")

        # a client iterates THROUGHOUT the repair: zero failed reads
        out4 = os.path.join(tmp, "client4")
        during = subprocess.Popen(
            [sys.executable, os.path.abspath(__file__), "--client",
             router_sock, out4, "6", "0.3"])

        # enqueue the repair, then SIGKILL the destination mid-copy: no
        # torn state may survive, and a retried repair must complete
        router_admin("--repair", f"node=n{victim}", "--no-wait")
        time.sleep(0.2 if faults_mode else 0.05)
        n3.send_signal(signal.SIGKILL)
        n3.wait(timeout=30)
        nodes.remove(n3)
        n3 = start_n3()
        nodes.append(n3)
        print("# destination SIGKILLed mid-copy and restarted")
        # the health loop marked n3 down when it died; make sure the
        # router sees it alive again before retrying, so the retried
        # copy resumes onto n3's staged chunks rather than re-homing
        with ClusterClient(router_sock) as probe:
            deadline = time.time() + 30
            while time.time() < deadline:
                if probe.node_health().get("n3"):
                    break
                time.sleep(0.2)
            else:
                raise RuntimeError("restarted n3 never came back up")
        router_admin("--repair", f"node=n{victim}", "--wait", "240")

        rc = during.wait(timeout=300)
        assert rc == 0, f"during-repair client exit code {rc}"
        for w, wave in enumerate(load_client(out4)):
            assert_wave_matches(wave, reference,
                                f"during-repair wave {w}")
        print("# zero failed reads during repair: 6/6 waves bit-identical")

        with ClusterClient(router_sock) as probe:
            placement = probe.placement()["assignments"]
            for v, reps in placement.items():
                assert f"n{victim}" not in reps, (v, reps)
                assert len(reps) == 2, (v, reps)
            final = run_workload(probe)
            assert_wave_matches([r.regions for r in final], reference,
                                "post-repair router read")
        # the rebuilt replica serves the post-retile generation: read it
        # DIRECTLY (bypassing the router) and check bits + epoch table
        from repro.core import RemoteVideoStore
        with RemoteVideoStore(n3_sock) as direct:
            n3_videos = [v for v, reps in placement.items()
                         if "n3" in reps]
            assert n3_videos, f"repair never placed anything on n3: " \
                              f"{placement}"
            if "cam0" in n3_videos:
                assert direct.epochs("cam0")[0] >= 1, \
                    "rebuilt replica still on the pre-retile epoch"
            for v, label, rng in WORKLOAD:
                if v not in n3_videos:
                    continue
                got = direct.scan(v).labels(label).frames(*rng).execute()
                i = WORKLOAD.index((v, label, rng))
                assert_same_regions(reference[i].regions, got.regions,
                                    f"n3 direct {v}")
        print(f"# repair restored K=2 onto n3 ({sorted(n3_videos)}); "
              f"rebuilt replica bit-identical, post-retile epoch")
        if proxy is not None:
            assert proxy.faults_fired >= 1, "faults never hit the stream"
            print(f"# chaos: {proxy.faults_fired} fault(s) injected into "
                  f"the copy path, repair converged anyway")

        # clean shutdown: SIGTERM -> exit 0, sockets unlinked
        router.send_signal(signal.SIGTERM)
        rc = router.wait(timeout=60)
        assert rc == 0, f"router exit code {rc}"
        assert not os.path.exists(router_sock), "router socket left behind"
        for i, proc in enumerate(nodes):
            if i == victim:
                continue
            proc.send_signal(signal.SIGTERM)
            rc = proc.wait(timeout=60)
            assert rc == 0, f"node n{i} exit code {rc}"
        print("# clean shutdown: router and surviving nodes exit 0")
        print("cluster_smoke,0.0,ok")
        return 0
    finally:
        if proxy is not None:
            proxy.close()
        for proc in ([router] if router else []) + nodes:
            if proc.poll() is None:
                proc.kill()
                proc.wait(timeout=30)


if __name__ == "__main__":
    raise SystemExit(main())
