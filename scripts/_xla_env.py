"""Shared XLA/runtime environment surface for the deployment scripts.

XLA reads ``XLA_FLAGS`` (and the other runtime env vars) exactly once,
when the backend initializes on first jax import — so deployment flags
must land in ``os.environ`` *before* anything imports ``repro.core``.
The scripts therefore parse args and call :func:`apply` first, and only
then import the engine inside ``main()``.

Typical CPU-serving knobs (composed, not replaced — anything already in
``XLA_FLAGS`` is kept):

    --xla-flags "--xla_cpu_multi_thread_eigen=false \
                 intra_op_parallelism_threads=1"
    --xla-flags "--xla_force_host_platform_device_count=8"
    --env TF_CPP_MIN_LOG_LEVEL=3 --env REPRO_DECODE_BACKEND=batched
"""
from __future__ import annotations

import argparse
import os


def add_args(ap: argparse.ArgumentParser) -> None:
    g = ap.add_argument_group(
        "runtime environment",
        "applied before the engine (and therefore XLA) initializes")
    g.add_argument("--xla-flags", default=None, metavar="FLAGS",
                   help="flags appended to XLA_FLAGS, e.g. "
                        '"--xla_cpu_multi_thread_eigen=false '
                        'intra_op_parallelism_threads=1" to pin the CPU '
                        "backend to one thread, or "
                        "--xla_force_host_platform_device_count=N for "
                        "multi-device runs")
    g.add_argument("--env", action="append", default=[], metavar="KEY=VAL",
                   help="set an environment variable before engine import "
                        "(repeatable), e.g. --env REPRO_DECODE_BACKEND="
                        "batched")


def apply(args: argparse.Namespace) -> None:
    """Install --env/--xla-flags into os.environ.  Must run before any
    repro.core (hence jax) import to have any effect on XLA."""
    for spec in args.env:
        key, sep, val = spec.partition("=")
        if not sep or not key:
            raise SystemExit(f"--env wants KEY=VAL, got {spec!r}")
        os.environ[key] = val
    if args.xla_flags:
        prev = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = f"{prev} {args.xla_flags}".strip()
