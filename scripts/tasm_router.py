#!/usr/bin/env python
"""Serve a cluster of TASM nodes behind one router socket.

    PYTHONPATH=src python scripts/tasm_router.py --socket /tmp/router.sock \
        --node a=/tmp/node-a.sock --node b=/tmp/node-b.sock \
        --node c=10.0.0.7:7841 --replication 2 \
        --placement /data/tasm/placement.json

Each ``--node name=addr`` names one running ``tasm_serve.py`` node (Unix
socket path or ``host:port``).  The router presents the exact same wire
protocol as a single node — clients connect with
:class:`repro.core.ClusterClient` (or plain ``RemoteVideoStore``) and get
the full declarative surface, routed: scans go to the video's replicas
(consistent-hash placement, persisted to ``--placement`` so restarts and
membership changes never silently re-home data), ``execute_many`` batches
fan out per node, and mutations write every replica.  With
``--replication K`` the cluster keeps serving a video's reads after K-1
of its nodes die.

Prints ``TASM router serving on <addr>`` once accepting.  SIGINT/SIGTERM
shut down cleanly (drain in-flight scans, close node channels, exit 0).
"""
from __future__ import annotations

import argparse
import os
import signal
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import _xla_env  # noqa: E402


def parse_nodes(specs) -> dict:
    nodes = {}
    for spec in specs:
        name, sep, addr = spec.partition("=")
        if not sep or not name or not addr:
            raise SystemExit(f"--node wants NAME=ADDR, got {spec!r}")
        if name in nodes:
            raise SystemExit(f"duplicate node name {name!r}")
        nodes[name] = addr
    return nodes


def parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    where = ap.add_mutually_exclusive_group(required=True)
    where.add_argument("--socket", metavar="PATH",
                       help="unix-domain socket path to listen on")
    where.add_argument("--tcp", metavar="HOST:PORT",
                       help="TCP address to listen on (PORT 0 = ephemeral)")
    ap.add_argument("--node", action="append", required=True,
                    metavar="NAME=ADDR",
                    help="a cluster node: unix socket path or host:port "
                         "(repeat per node)")
    ap.add_argument("--replication", type=int, default=1, metavar="K",
                    help="replicas per video (default 1; capped at the "
                         "node count)")
    ap.add_argument("--placement", default=None, metavar="FILE",
                    help="persisted placement map (loaded when it exists, "
                         "written on every assignment)")
    ap.add_argument("--max-frame-mb", type=int, default=None,
                    help="reject wire frames larger than this many MiB "
                         "(default 256)")
    ap.add_argument("--codec", default=None, choices=("msgpack", "json"),
                    help="wire codec (default: msgpack when installed, "
                         "else json)")
    ap.add_argument("--node-retries", type=int, default=1,
                    help="per-channel reconnect retries for idempotent "
                         "node RPCs (default 1)")
    _xla_env.add_args(ap)
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv)
    # env must land before the engine (hence XLA) initializes
    _xla_env.apply(args)
    from repro.core import ClusterRouter, ClusterRouterServer, wire
    kw: dict = {}
    if args.socket:
        kw["path"] = args.socket
    else:
        host, _, port = args.tcp.rpartition(":")
        kw["host"], kw["port"] = host or "127.0.0.1", int(port)
    rkw: dict = {}
    if args.max_frame_mb is not None:
        rkw["max_frame_bytes"] = kw["max_frame_bytes"] = \
            args.max_frame_mb << 20
    router = ClusterRouter(parse_nodes(args.node),
                           replication=args.replication,
                           placement_path=args.placement,
                           codec=args.codec, node_retries=args.node_retries,
                           **rkw)
    server = ClusterRouterServer(router, codec=args.codec, **kw)
    server.start()

    def _shutdown(signum, frame):
        server.stop()

    signal.signal(signal.SIGTERM, _shutdown)
    signal.signal(signal.SIGINT, _shutdown)
    down = sorted(router._down)
    print(f"TASM router serving on {server.address} "
          f"(pid {os.getpid()}, codec {args.codec or wire.default_codec()}, "
          f"nodes {sorted(router.addresses)}, replication "
          f"{router.placement.replication}"
          + (f", DOWN {down}" if down else "") + ")", flush=True)
    server.serve_forever()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
