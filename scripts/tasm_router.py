#!/usr/bin/env python
"""Serve a cluster of TASM nodes behind one router socket — or administer
a running router (repair / rebalance / status).

Serve::

    PYTHONPATH=src python scripts/tasm_router.py --socket /tmp/router.sock \
        --node a=/tmp/node-a.sock --node b=/tmp/node-b.sock \
        --node c=10.0.0.7:7841 --replication 2 \
        --placement /data/tasm/placement.json --timeout 30 \
        --health-interval 5

Each ``--node name=addr`` names one running ``tasm_serve.py`` node (Unix
socket path or ``host:port``).  The router presents the exact same wire
protocol as a single node — clients connect with
:class:`repro.core.ClusterClient` (or plain ``RemoteVideoStore``) and get
the full declarative surface, routed: scans go to the video's replicas
(consistent-hash placement, persisted to ``--placement`` so restarts and
membership changes never silently re-home data), ``execute_many`` batches
fan out per node, and mutations write every replica.  With
``--replication K`` the cluster keeps serving a video's reads after K-1
of its nodes die.  ``--timeout`` puts a per-RPC deadline on every node
call (a hung node fails over instead of blocking a serving thread);
``--health-interval`` starts the background health loop that revives
recovered nodes automatically.

Administer (point ``--socket``/``--tcp`` at a RUNNING router)::

    tasm_router.py --socket /tmp/router.sock --repair node=b
    tasm_router.py --socket /tmp/router.sock --repair video=cam3
    tasm_router.py --socket /tmp/router.sock --repair            # heal all
    tasm_router.py --socket /tmp/router.sock --rebalance         # plan only
    tasm_router.py --socket /tmp/router.sock --rebalance --apply
    tasm_router.py --socket /tmp/router.sock --join-node d=/tmp/node-d.sock
    tasm_router.py --socket /tmp/router.sock --repair-status

``--repair``/``--rebalance --apply`` enqueue background copy jobs and then
wait for them (``--wait SECONDS`` bounds the wait; ``--no-wait`` returns
immediately).  Exit status 0 iff every job completed; per-job
chunks/bytes/retries are printed either way.

Prints ``TASM router serving on <addr>`` once accepting.  SIGINT/SIGTERM
shut down cleanly (drain in-flight scans, close node channels, exit 0).
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import _xla_env  # noqa: E402


def parse_nodes(specs) -> dict:
    nodes = {}
    for spec in specs:
        name, sep, addr = spec.partition("=")
        if not sep or not name or not addr:
            raise SystemExit(f"--node wants NAME=ADDR, got {spec!r}")
        if name in nodes:
            raise SystemExit(f"duplicate node name {name!r}")
        nodes[name] = addr
    return nodes


def parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    where = ap.add_mutually_exclusive_group(required=True)
    where.add_argument("--socket", metavar="PATH",
                       help="unix-domain socket path (listen on it when "
                            "serving; connect to it in admin modes)")
    where.add_argument("--tcp", metavar="HOST:PORT",
                       help="TCP address (PORT 0 = ephemeral when serving)")
    ap.add_argument("--node", action="append", metavar="NAME=ADDR",
                    help="a cluster node: unix socket path or host:port "
                         "(repeat per node; serve mode only)")
    ap.add_argument("--replication", type=int, default=1, metavar="K",
                    help="replicas per video (default 1; capped at the "
                         "node count)")
    ap.add_argument("--placement", default=None, metavar="FILE",
                    help="persisted placement map (loaded when it exists, "
                         "written on every assignment)")
    ap.add_argument("--max-frame-mb", type=int, default=None,
                    help="reject wire frames larger than this many MiB "
                         "(default 256)")
    ap.add_argument("--codec", default=None, choices=("msgpack", "json"),
                    help="wire codec (default: msgpack when installed, "
                         "else json)")
    ap.add_argument("--node-retries", type=int, default=1,
                    help="per-channel reconnect retries for idempotent "
                         "node RPCs (default 1)")
    ap.add_argument("--timeout", type=float, default=None, metavar="S",
                    help="per-RPC deadline on node calls: a hung node "
                         "raises and fails over (default: none)")
    ap.add_argument("--health-interval", type=float, default=None,
                    metavar="S",
                    help="probe nodes about this often in the background "
                         "so recovered nodes rejoin (default: off — "
                         "revival happens on explicit node_health)")
    adm = ap.add_argument_group("admin modes (against a running router)")
    adm.add_argument("--repair", nargs="?", const="", default=None,
                     metavar="video=V|node=N",
                     help="re-replicate: one video, everything a lost "
                          "node held, or (no value) every "
                          "under-replicated video")
    adm.add_argument("--rebalance", action="store_true",
                     help="print the placement moves a rebalance would "
                          "make (add --apply to stream them)")
    adm.add_argument("--apply", action="store_true",
                     help="with --rebalance: actually move the data")
    adm.add_argument("--join-node", metavar="NAME=ADDR", default=None,
                     help="register a (fresh) node with the router")
    adm.add_argument("--repair-status", action="store_true",
                     help="print per-job progress + worker totals as JSON")
    adm.add_argument("--show-config", action="store_true",
                     help="print each node's resolved cache/tuning/decode "
                          "configuration as JSON (see core/config.py)")
    adm.add_argument("--wait", type=float, default=None, metavar="S",
                     help="admin: bound the wait for enqueued jobs "
                          "(default: wait until they settle)")
    adm.add_argument("--no-wait", action="store_true",
                     help="admin: enqueue and exit without waiting")
    _xla_env.add_args(ap)
    args = ap.parse_args(argv)
    args.admin = (args.repair is not None or args.rebalance
                  or args.repair_status or args.join_node is not None
                  or args.show_config)
    if args.admin and args.node:
        ap.error("--node is for serve mode; admin modes talk to a "
                 "running router")
    if not args.admin and not args.node:
        ap.error("serve mode needs at least one --node NAME=ADDR")
    return args


def _addr_kwargs(args) -> dict:
    if args.socket:
        return {"path": args.socket}
    host, _, port = args.tcp.rpartition(":")
    return {"host": host or "127.0.0.1", "port": int(port)}


def _print_jobs(jobs) -> None:
    for j in jobs:
        line = (f"  [{j['job_id']}] {j['kind']} {j['video']}: "
                f"{j['src'] or '?'} -> {j['dst']}  {j['status']}  "
                f"chunks {j['chunks_done']}/{j['chunks_total']}  "
                f"{j['bytes_copied'] / 1e6:.2f} MB  "
                f"retries {j['retries']}  restreams {j['restreams']}")
        if j["error"]:
            line += f"  error: {j['error']}"
        print(line, flush=True)


def admin(args) -> int:
    from repro.core import ClusterClient
    with ClusterClient(**_addr_kwargs(args), codec=args.codec) as c:
        if args.repair_status:
            print(json.dumps(c.repair_status(), indent=1, sort_keys=True))
            return 0
        if args.show_config:
            doc = c.config()

            def as_doc(d):
                return {k: v.to_doc() for k, v in d.items()}

            out = {"nodes": {n: None if d is None else as_doc(d)
                             for n, d in doc["nodes"].items()}} \
                if "nodes" in doc else as_doc(doc)
            print(json.dumps(out, indent=1, sort_keys=True))
            return 0
        if args.join_node is not None:
            (name, addr), = parse_nodes([args.join_node]).items()
            out = c.join_node(name, addr)
            print(f"joined {name} ({'alive' if out['alive'] else 'DOWN'}); "
                  f"nodes: {out['nodes']}", flush=True)
            if not (args.repair is not None or args.rebalance):
                return 0
        enqueued = []
        if args.repair is not None:
            target: dict = {}
            if args.repair:
                k, sep, v = args.repair.partition("=")
                if not sep or k not in ("video", "node"):
                    raise SystemExit(
                        f"--repair wants video=V or node=N, "
                        f"got {args.repair!r}")
                target[k] = v
            enqueued = c.repair(**target)
            print(f"repair: {len(enqueued)} copy job(s) enqueued",
                  flush=True)
        if args.rebalance:
            doc = c.rebalance(apply=args.apply)
            for v, (cur, new) in sorted(doc["moves"].items()):
                print(f"  move {v}: {cur} -> {new}", flush=True)
            if not doc["moves"]:
                print("rebalance: nothing to move", flush=True)
            if not args.apply:
                return 0
            enqueued += doc["jobs"]
            flipped = doc.get("flipped") or []
            if flipped:
                print(f"rebalance: flipped in place: {flipped}",
                      flush=True)
            print(f"rebalance: {len(doc['jobs'])} copy job(s) enqueued",
                  flush=True)
        _print_jobs(enqueued)
        if args.no_wait or not enqueued:
            return 0
        ids = {j["job_id"] for j in enqueued}
        note = None
        try:
            status = c.drain_repair(timeout=args.wait)
        except Exception as e:  # noqa: BLE001 - job failure or timeout
            # drain re-raises the most recent job failure — which may be
            # an EARLIER round's job this retry just healed around.  The
            # verdict is the fate of the jobs WE enqueued.
            note = e
            status = c.repair_status()
        mine = [j for j in status["jobs"] if j["job_id"] in ids]
        _print_jobs(mine)
        if all(j["status"] == "done" for j in mine):
            if note is not None:
                print(f"note: an earlier repair attempt had failed "
                      f"({note}); this one completed", flush=True)
            return 0
        print(f"repair did not settle cleanly"
              + (f": {note}" if note else ""), file=sys.stderr, flush=True)
        return 1


def main(argv=None) -> int:
    args = parse_args(argv)
    if args.admin:
        return admin(args)
    # env must land before the engine (hence XLA) initializes
    _xla_env.apply(args)
    from repro.core import ClusterRouter, ClusterRouterServer, wire
    kw: dict = _addr_kwargs(args)
    rkw: dict = {}
    if args.max_frame_mb is not None:
        rkw["max_frame_bytes"] = kw["max_frame_bytes"] = \
            args.max_frame_mb << 20
    router = ClusterRouter(parse_nodes(args.node),
                           replication=args.replication,
                           placement_path=args.placement,
                           codec=args.codec, node_retries=args.node_retries,
                           timeout=args.timeout,
                           health_interval=args.health_interval,
                           **rkw)
    server = ClusterRouterServer(router, codec=args.codec, **kw)
    server.start()

    def _shutdown(signum, frame):
        server.stop()

    signal.signal(signal.SIGTERM, _shutdown)
    signal.signal(signal.SIGINT, _shutdown)
    down = sorted(router._down)
    print(f"TASM router serving on {server.address} "
          f"(pid {os.getpid()}, codec {args.codec or wire.default_codec()}, "
          f"nodes {sorted(router.addresses)}, replication "
          f"{router.placement.replication}"
          + (f", DOWN {down}" if down else "") + ")", flush=True)
    server.serve_forever()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
