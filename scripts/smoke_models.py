"""Dev smoke: one forward/loss + one decode step per reduced arch on CPU."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config, reduce_config
from repro.models import zoo

ONLY = sys.argv[1:] or ARCH_IDS


def fake_batch(cfg, B=2, S=64, key=None):
    key = key or jax.random.key(0)
    batch = {}
    if cfg.frontend == "patch":
        n_img = min(cfg.frontend_tokens, S // 4)
        batch["patch_embeds"] = jax.random.normal(key, (B, n_img, cfg.frontend_dim))
        batch["tokens"] = jax.random.randint(key, (B, S - n_img), 0, cfg.vocab)
        batch["targets"] = jax.random.randint(key, (B, S - n_img), 0, cfg.vocab)
    elif cfg.is_encdec:
        batch["frames"] = jax.random.normal(key, (B, S // 4, cfg.d_model))
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
        batch["targets"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
        batch["targets"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return batch


for arch in ONLY:
    cfg = reduce_config(get_config(arch))
    key = jax.random.key(42)
    params = zoo.init_model(cfg, key)
    batch = fake_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: zoo.loss_fn(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"

    # decode one token
    B, max_len = 2, 64
    caches = zoo.init_cache(cfg, B, max_len)
    dbatch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    if cfg.is_encdec:
        dbatch["enc_out"] = jnp.zeros((B, 16, cfg.d_model))
    logits, caches = jax.jit(
        lambda p, b, c: zoo.decode_step(p, cfg, b, c, cache_index=jnp.int32(3))
    )(params, dbatch, caches)
    assert logits.shape == (B, 1, cfg.vocab), f"{arch}: bad logits {logits.shape}"
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: logits not finite"
    n_params = zoo.analytic_param_count(cfg)
    print(f"OK {arch:26s} loss={float(loss):8.4f} params={n_params:,}")
print("ALL OK")
