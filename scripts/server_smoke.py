#!/usr/bin/env python
"""Cross-process serving smoke: start ``tasm_serve.py`` on a Unix socket,
run two concurrent client PROCESSES, and assert the serving contract —
once per reply transport (``--transport both``, the default, runs the
whole smoke twice: a ``--transport shm`` server and a ``--transport
socket`` one):

- both clients' results are bit-identical to an in-process ``execute()``
  of the same scans on an identically-built local store;
- every client negotiated the transport its server was started with
  (``shm`` server -> clients report ``shm``; ``socket`` server -> ``npz``);
- a repeat of the workload by a fresh client process decodes ZERO tiles
  (the tile cache is shared across the process boundary);
- under shm, the server's segment pool drains back to zero once the
  client processes exit (no leaked leases);
- SIGTERM shuts the server down cleanly (exit code 0, socket file gone,
  no orphaned process).

Exits non-zero on any violation — this is the CI server-smoke step::

    python scripts/server_smoke.py --transport shm

The script doubles as its own client: ``server_smoke.py --client SOCK OUT``
connects, runs the canonical workload, and writes results to ``OUT.npz`` +
``OUT.json`` for the parent to compare.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import signal
import socket
import subprocess
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.codec.encode import EncoderConfig  # noqa: E402
from repro.core import (NoTilingPolicy, RemoteVideoStore,  # noqa: E402
                        VideoStore)
from repro.data.video_gen import generate, sparse_spec  # noqa: E402

ENC = EncoderConfig(gop=16, qp=8)
N_FRAMES, H, W = 48, 96, 160
#: the canonical two-client workload: overlapping windows over two labels
WORKLOAD = [("car", (0, 32)), ("person", (16, 48)), ("car", (16, 48)),
            ("car", (0, 48))]
#: client-visible transport expected per server transport flag
EXPECT = {"shm": "shm", "socket": "npz"}


def corpus():
    return generate(sparse_spec(seed=3, n_frames=N_FRAMES, height=H,
                                width=W))


def run_workload(store):
    return [store.scan("cam0").labels(label).frames(*rng).execute()
            for label, rng in WORKLOAD]


# --------------------------------------------------------------- client
def client_main(sock_path: str, out: str) -> int:
    with RemoteVideoStore(sock_path) as cli:
        transport = cli.transport
        results = run_workload(cli)
        arrays, meta = {}, []
        for i, r in enumerate(results):
            regs = []
            for j, (f, box, px) in enumerate(r.regions):
                arrays[f"px_{i}_{j}"] = np.ascontiguousarray(px)
                regs.append([f, list(box)])
            meta.append({"regions": regs,
                         "cache_misses": r.stats.cache_misses,
                         "cache_hits": r.stats.cache_hits,
                         "transport": transport,
                         "marshal_s": r.stats.marshal_s,
                         "payload_bytes": r.stats.payload_bytes})
    np.savez(out + ".npz", **arrays)
    pathlib.Path(out + ".json").write_text(json.dumps(meta))
    return 0


def load_client(out: str):
    meta = json.loads(pathlib.Path(out + ".json").read_text())
    npz = np.load(out + ".npz")
    results = []
    for i, m in enumerate(meta):
        regions = [(f, tuple(box), npz[f"px_{i}_{j}"])
                   for j, (f, box) in enumerate(m["regions"])]
        results.append((regions, m))
    return results


def assert_same_regions(a, b, where: str) -> None:
    assert len(a) == len(b), f"{where}: {len(a)} vs {len(b)} regions"
    for ra, rb in zip(a, b):
        assert ra[:-1] == rb[:-1], f"{where}: region keys diverge"
        if not np.array_equal(ra[-1], rb[-1]):
            raise AssertionError(f"{where}: pixels not bit-identical at "
                                 f"frame {ra[0]}")


# --------------------------------------------------------------- parent
def wait_for_socket(path: str, proc, timeout: float = 60.0) -> None:
    deadline = time.time() + timeout
    while time.time() < deadline:
        if proc.poll() is not None:
            raise RuntimeError(f"server died early (rc={proc.returncode})")
        if os.path.exists(path):
            s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            try:
                s.connect(path)
                return
            except OSError:
                pass
            finally:
                s.close()
        time.sleep(0.05)
    raise RuntimeError("server socket never came up")


def smoke(transport: str) -> None:
    """One full smoke pass against a ``--transport <transport>`` server."""
    expected = EXPECT[transport]
    tmp = tempfile.mkdtemp(prefix=f"tasm_smoke_{transport}_")
    sock_path = os.path.join(tmp, "tasm.sock")
    here = os.path.dirname(os.path.abspath(__file__))
    server = subprocess.Popen(
        [sys.executable, os.path.join(here, "tasm_serve.py"),
         "--socket", sock_path, "--transport", transport])
    try:
        wait_for_socket(sock_path, server)
        frames, dets = corpus()

        # seed the server's store over the wire, and build the in-process
        # reference store identically (encode is deterministic)
        with RemoteVideoStore(sock_path) as seed:
            seed.add_video("cam0", encoder=ENC, policy=NoTilingPolicy())
            seed.ingest("cam0", frames)
            seed.add_detections("cam0", {f: d for f, d in enumerate(dets)})
        local = VideoStore()
        local.add_video("cam0", encoder=ENC, policy=NoTilingPolicy())
        local.ingest("cam0", frames)
        local.add_detections("cam0", {f: d for f, d in enumerate(dets)})
        reference = run_workload(local)

        # two concurrent client processes over one server
        outs = [os.path.join(tmp, f"client{i}") for i in (1, 2)]
        clients = [subprocess.Popen(
            [sys.executable, os.path.abspath(__file__), "--client",
             sock_path, out]) for out in outs]
        rcs = [c.wait(timeout=300) for c in clients]
        assert rcs == [0, 0], f"client exit codes {rcs}"
        got = [load_client(out) for out in outs]
        for out in got:
            for _, m in out:
                assert m["transport"] == expected, (
                    f"client negotiated {m['transport']!r}, expected "
                    f"{expected!r} from a --transport {transport} server")
        for (regions, _), ref in zip(got[0], reference):
            assert_same_regions(ref.regions, regions, "client1 vs local")
        for (r1, _), (r2, _) in zip(got[0], got[1]):
            assert_same_regions(r1, r2, "client1 vs client2")
        marshal = sum(m["marshal_s"] for out in got for _, m in out)
        print(f"# [{transport}] two concurrent clients bit-identical to "
              f"in-process execute "
              f"({sum(len(r) for r, _ in got[0])} regions, "
              f"negotiated {expected}, marshal {marshal:.4f}s)")

        # a fresh third process repeating the workload must decode nothing
        with RemoteVideoStore(sock_path) as probe:
            tiles_before = probe.stats()["tiles_decoded_total"]
        out3 = os.path.join(tmp, "client3")
        rc = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--client",
             sock_path, out3], timeout=300).returncode
        assert rc == 0, f"repeat client exit code {rc}"
        repeat = load_client(out3)
        misses = sum(m["cache_misses"] for _, m in repeat)
        with RemoteVideoStore(sock_path) as probe:
            tiles_after = probe.stats()["tiles_decoded_total"]
        assert misses == 0, f"repeat client had {misses} cache misses"
        assert tiles_after == tiles_before, (
            f"repeat client decoded {tiles_after - tiles_before} tiles")
        for (r1, _), (r3, _) in zip(got[0], repeat):
            assert_same_regions(r1, r3, "client1 vs warm repeat")
        print(f"# [{transport}] warm repeat from a fresh process decoded "
              f"0 tiles ({misses} misses)")

        # no leaked leases: with every client gone, the pool drains to 0
        # (poll briefly — the connection-drop release can lag the client
        # process's exit by a scheduler tick)
        if transport == "shm":
            deadline = time.time() + 30
            with RemoteVideoStore(sock_path, transport="socket") as probe:
                while True:
                    shm_stats = probe.stats().get("shm")
                    assert shm_stats is not None, "server lost shm stats"
                    if shm_stats["segments"] == 0:
                        break
                    assert time.time() < deadline, (
                        f"segment pool leaked {shm_stats['segments']} "
                        f"segments ({shm_stats['bytes']} bytes) after "
                        f"clients exited")
                    time.sleep(0.1)
            print(f"# [{transport}] segment pool drained to 0 after "
                  f"clients exited")

        # clean shutdown: SIGTERM -> exit 0, socket unlinked, no orphan
        server.send_signal(signal.SIGTERM)
        rc = server.wait(timeout=60)
        assert rc == 0, f"server exit code {rc}"
        assert not os.path.exists(sock_path), "socket file left behind"
        print(f"# [{transport}] clean shutdown: exit 0, socket removed")
    finally:
        if server.poll() is None:
            server.kill()
            server.wait(timeout=30)


def main() -> int:
    if len(sys.argv) > 1 and sys.argv[1] == "--client":
        return client_main(sys.argv[2], sys.argv[3])
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--transport", default="both",
                    choices=("shm", "socket", "both"),
                    help="which reply transport(s) to smoke (default both)")
    args = ap.parse_args()
    transports = (["shm", "socket"] if args.transport == "both"
                  else [args.transport])
    for transport in transports:
        smoke(transport)
    print("server_smoke,0.0,ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
