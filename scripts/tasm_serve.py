#!/usr/bin/env python
"""Serve a VideoStore to many client processes over a socket.

    PYTHONPATH=src python scripts/tasm_serve.py --socket /tmp/tasm.sock \
        --store-root /data/tasm
    PYTHONPATH=src python scripts/tasm_serve.py --tcp 0.0.0.0:7841

Clients connect with :class:`repro.core.RemoteVideoStore` (same declarative
surface — ``scan(v).labels(...).frames(...).execute()``, ``execute_many``,
``serve()`` sessions, ``ingest``/``add_detections``/``retile``/…) and share
ONE scheduler, tile cache, and background tuner, so overlapping queries
from different processes merge their decodes and warm each other.

Prints ``TASM serving on <addr>`` once the socket is accepting (CI and
scripts wait for that line or for the socket file).  SIGINT/SIGTERM shut
down cleanly: stop accepting, drain in-flight scans, flush the tuner and
manifests, exit 0.
"""
from __future__ import annotations

import argparse
import os
import signal
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import _xla_env  # noqa: E402


def parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    where = ap.add_mutually_exclusive_group(required=True)
    where.add_argument("--socket", metavar="PATH",
                       help="unix-domain socket path to listen on")
    where.add_argument("--tcp", metavar="HOST:PORT",
                       help="TCP address to listen on (PORT 0 = ephemeral)")
    ap.add_argument("--store-root", default=None,
                    help="durable store root (omit for an in-memory store)")
    # --cache-*: one flag per CacheConfig field (core/config.py)
    ap.add_argument("--cache-bytes", type=int, default=None,
                    help="decoded-tile cache budget (default: "
                         "$REPRO_CACHE_BYTES, else 256 MiB; 0 disables)")
    ap.add_argument("--tile-cache-bytes", type=int, default=None,
                    help=argparse.SUPPRESS)  # deprecated: --cache-bytes
    ap.add_argument("--cache-eviction", default=None,
                    choices=("reuse", "lru"),
                    help="eviction policy: expected-reuse weighting, or "
                         "the legacy pure LRU (default: "
                         "$REPRO_CACHE_EVICTION, else reuse)")
    ap.add_argument("--cache-prefetch", action="store_true",
                    help="predictively decode the next SOTs of detected "
                         "sliding-window scans (off by default)")
    ap.add_argument("--cache-prefetch-depth", type=int, default=2,
                    help="how many SOTs ahead to prefetch (default 2)")
    ap.add_argument("--no-cache-block-packed", dest="cache_block_packed",
                    action="store_false", default=True,
                    help="store ROI cache entries as zero-padded full-tile "
                         "canvases instead of packed blocks")
    ap.add_argument("--tuning", default="background",
                    choices=("background", "inline", "off"))
    ap.add_argument("--tuner-admission", default="policy",
                    choices=("policy", "gated"),
                    help="background tuner admission: apply every policy "
                         "proposal, or gate + rank by what-if net benefit")
    ap.add_argument("--max-frame-mb", type=int, default=None,
                    help="reject wire frames larger than this many MiB "
                         "(default 256)")
    ap.add_argument("--codec", default=None, choices=("msgpack", "json"),
                    help="wire codec for responses (default: msgpack when "
                         "installed, else json)")
    ap.add_argument("--transport", default=None,
                    choices=("shm", "socket", "auto"),
                    help="scan-reply transport: shm = require the "
                         "zero-copy shared-memory path, socket = npz "
                         "payloads only, auto = offer shm to clients "
                         "that prove they share /dev/shm (default: "
                         "$REPRO_TRANSPORT, else auto)")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="micro-batch cap of the shared serving session")
    ap.add_argument("--decode-backend", default=None,
                    choices=("numpy", "batched"),
                    help="decode_tiles implementation: per-tile numpy loop "
                         "or fused accelerator batches (default: "
                         "$REPRO_DECODE_BACKEND, else numpy)")
    _xla_env.add_args(ap)
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv)
    # env must land before the engine (hence XLA) initializes
    _xla_env.apply(args)
    from repro.core import (CacheConfig, DecodeConfig, TuningConfig,
                            VideoStore, VideoStoreServer, wire)
    kw: dict = {}
    if args.socket:
        kw["path"] = args.socket
    else:
        host, _, port = args.tcp.rpartition(":")
        kw["host"], kw["port"] = host or "127.0.0.1", int(port)
    if args.max_frame_mb is not None:
        kw["max_frame_bytes"] = args.max_frame_mb << 20
    cache_bytes = args.cache_bytes if args.cache_bytes is not None \
        else args.tile_cache_bytes
    store = VideoStore(
        store_root=args.store_root,
        cache=CacheConfig(budget_bytes=cache_bytes,
                          eviction=args.cache_eviction,
                          prefetch=args.cache_prefetch,
                          prefetch_depth=args.cache_prefetch_depth,
                          block_packed=args.cache_block_packed),
        tuning=TuningConfig(mode=args.tuning,
                            admission=args.tuner_admission),
        decode=DecodeConfig(backend=args.decode_backend))
    server = VideoStoreServer(store, codec=args.codec,
                              max_batch=args.max_batch,
                              transport=args.transport, **kw)
    server.start()

    def _shutdown(signum, frame):
        server.stop()

    signal.signal(signal.SIGTERM, _shutdown)
    signal.signal(signal.SIGINT, _shutdown)
    print(f"TASM serving on {server.address} "
          f"(pid {os.getpid()}, codec {args.codec or wire.default_codec()}, "
          f"transport {server.transport}, "
          f"store {args.store_root or '<memory>'})", flush=True)
    server.serve_forever()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
