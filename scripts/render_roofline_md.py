"""Render EXPERIMENTS.md roofline/dry-run tables from the dry-run JSONLs."""
import json
import pathlib
import sys

RES = pathlib.Path("results/dryrun")


def load(path):
    rows = {}
    for line in (RES / path).read_text().splitlines():
        try:
            r = json.loads(line)
            rows[(r["arch"], r["shape"])] = r
        except json.JSONDecodeError:
            pass
    return rows


def fmt(x):
    return f"{x:.2e}"


def roofline_table(rows, baseline=None):
    out = ["| arch | shape | dominant | compute_s | memory_s | collective_s | "
           "useful | GB/dev | fits |",
           "|---|---|---|---|---|---|---|---|---|"]
    for (a, s), r in sorted(rows.items()):
        if r["status"] == "skipped":
            out.append(f"| {a} | {s} | — | — | — | — | — | — | skipped (full attention @500k) |")
            continue
        t = r["roofline"]
        gb = r["memory"].get("total_device_bytes", 0) / 1e9
        out.append(
            f"| {a} | {s} | {t['dominant']} | {fmt(t['compute_s'])} | "
            f"{fmt(t['memory_s'])} | {fmt(t['collective_s'])} | "
            f"{t['useful_ratio']:.2f} | {gb:.1f} | {r.get('fits_hbm')} |")
    return "\n".join(out)


def delta_table(base, opt):
    out = ["| arch | shape | dominant (base→opt) | dominant-term s (base→opt) | Δ |",
           "|---|---|---|---|---|"]
    for key in sorted(base):
        b, o = base[key], opt.get(key)
        if b["status"] != "ok" or not o or o["status"] != "ok":
            continue
        tb, to = b["roofline"], o["roofline"]
        db = max(tb["compute_s"], tb["memory_s"], tb["collective_s"])
        do = max(to["compute_s"], to["memory_s"], to["collective_s"])
        delta = (db - do) / db * 100
        out.append(f"| {key[0]} | {key[1]} | {tb['dominant']}→{to['dominant']} | "
                   f"{fmt(db)}→{fmt(do)} | {delta:+.0f}% |")
    return "\n".join(out)


def mfu_summary(rows):
    """Projected roofline fraction = useful compute / dominant term."""
    out = ["| arch | shape | projected roofline fraction |", "|---|---|---|"]
    for (a, s), r in sorted(rows.items()):
        if r["status"] != "ok":
            continue
        t = r["roofline"]
        dom = max(t["compute_s"], t["memory_s"], t["collective_s"])
        frac = t["useful_ratio"] * t["compute_s"] / dom if dom else 0
        out.append(f"| {a} | {s} | {frac:.3f} |")
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    base_s = load("16_16_baseline.jsonl")
    opt_s = load("16_16.jsonl")
    opt_m = load("2_16_16.jsonl")
    if which in ("all", "baseline"):
        print("### Single-pod 16x16 — BASELINE (paper-faithful sharding)\n")
        print(roofline_table(base_s))
    if which in ("all", "optimized"):
        print("\n### Single-pod 16x16 — OPTIMIZED\n")
        print(roofline_table(opt_s))
        print("\n### Multi-pod 2x16x16 — OPTIMIZED\n")
        print(roofline_table(opt_m))
    if which in ("all", "delta"):
        print("\n### Baseline -> optimized, dominant term per cell\n")
        print(delta_table(base_s, opt_s))
    if which in ("all", "mfu"):
        print("\n### Projected roofline fractions (optimized, single-pod)\n")
        print(mfu_summary(opt_s))
