"""Compare tiling strategies over a shifting query workload (paper §5.3 W4:
queries move car -> person -> car) and print the cumulative cost table.

    PYTHONPATH=src python examples/incremental_workload.py
"""
import numpy as np

from repro.codec.encode import EncoderConfig
from repro.core import (MorePolicy, NoTilingPolicy, PretileAllPolicy,
                        RegretPolicy, VideoStore)
from repro.core.calibrate import calibrated_cost_model
from repro.data.video_gen import generate, sparse_spec

ENC = EncoderConfig(gop=16, qp=8)
N_FRAMES, N_QUERIES, WINDOW = 256, 60, 32

spec = sparse_spec(seed=1, n_frames=N_FRAMES)
frames, dets = generate(spec)
model = calibrated_cost_model(ENC, seeds=(0,), repeats=1)

rng = np.random.default_rng(0)
starts = rng.integers(0, N_FRAMES - WINDOW, N_QUERIES)
labels = (["car"] * (N_QUERIES // 3) + ["person"] * (N_QUERIES // 3)
          + ["car"] * (N_QUERIES - 2 * (N_QUERIES // 3)))
queries = list(zip(labels, [(int(s), int(s) + WINDOW) for s in starts]))

results = {}
for name, policy_cls in [("not_tiled", NoTilingPolicy),
                         ("all_objects", PretileAllPolicy),
                         ("incremental_more", MorePolicy),
                         ("incremental_regret", RegretPolicy)]:
    # cache off: this example compares decode cost across tiling policies
    store = VideoStore(tile_cache_bytes=0)
    store.add_video("v", encoder=ENC, policy=policy_cls(), cost_model=model)
    store.add_detections("v", {f: d for f, d in enumerate(dets)})
    pre = store.ingest("v", frames).pretile_s
    cum = pre if name == "all_objects" else 0.0
    series = []
    for label, t_range in queries:
        st = store.scan("v").labels(label).frames(*t_range).execute().stats
        cum += st.decode_s + st.lookup_s + st.retile_s
        series.append(cum)
    results[name] = np.array(series)
    print(f"{name:20s} final cumulative = {cum:6.2f}s  layouts: "
          f"{[r.layout.describe() for r in store.video('v').store.sots[:6]]}"
          "...")

base = results["not_tiled"]
print("\ncumulative cost normalized to not_tiled (paper Fig. 11d):")
for name, series in results.items():
    pts = [f"{100 * series[i] / base[i]:5.0f}%" for i in
           (9, N_QUERIES // 2, N_QUERIES - 1)]
    print(f"  {name:20s} @q10/q{N_QUERIES//2}/q{N_QUERIES}: {' '.join(pts)}")
