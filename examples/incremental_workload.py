"""Compare tiling strategies over a shifting query workload (paper §5.3 W4:
queries move car -> person -> car), print the cumulative cost table, then
demo the background physical tuner: the same regret-tuned workload with
re-tiling moved off the scan path (``tuning="background"`` +
``drain_tuner()``), converging to the same layouts with no query ever
charged re-encode time.

    PYTHONPATH=src python examples/incremental_workload.py
"""
import numpy as np

from repro.codec.encode import EncoderConfig
from repro.core import (CacheConfig, DecodeConfig, MorePolicy,
                        NoTilingPolicy, PretileAllPolicy, TuningConfig,
                        RegretPolicy, VideoStore)
from repro.core.calibrate import calibrated_cost_model
from repro.data.video_gen import generate, sparse_spec

ENC = EncoderConfig(gop=16, qp=8)
N_FRAMES, N_QUERIES, WINDOW = 256, 60, 32

spec = sparse_spec(seed=1, n_frames=N_FRAMES)
frames, dets = generate(spec)
model = calibrated_cost_model(ENC, seeds=(0,), repeats=1)

rng = np.random.default_rng(0)
starts = rng.integers(0, N_FRAMES - WINDOW, N_QUERIES)
labels = (["car"] * (N_QUERIES // 3) + ["person"] * (N_QUERIES // 3)
          + ["car"] * (N_QUERIES - 2 * (N_QUERIES // 3)))
queries = list(zip(labels, [(int(s), int(s) + WINDOW) for s in starts]))


def make_store(policy_cls, tuning):
    # cache off + ROI decode off: this example compares full-tile decode
    # cost across tiling policies (ROI-restricted decode would flatten it)
    store = VideoStore(cache=CacheConfig(budget_bytes=0),
                       tuning=TuningConfig(mode=tuning),
                       decode=DecodeConfig(roi=False))
    store.add_video("v", encoder=ENC, policy=policy_cls(), cost_model=model)
    store.add_detections("v", {f: d for f, d in enumerate(dets)})
    return store


results = {}
for name, policy_cls in [("not_tiled", NoTilingPolicy),
                         ("all_objects", PretileAllPolicy),
                         ("incremental_more", MorePolicy),
                         ("incremental_regret", RegretPolicy)]:
    # inline tuning: this table charges re-tiling to the triggering query
    # (the paper's cumulative-cost accounting)
    store = make_store(policy_cls, "inline")
    pre = store.ingest("v", frames).pretile_s
    cum = pre if name == "all_objects" else 0.0
    series = []
    for label, t_range in queries:
        st = store.scan("v").labels(label).frames(*t_range).execute().stats
        cum += st.decode_s + st.lookup_s + st.retile_s
        series.append(cum)
    results[name] = np.array(series)
    print(f"{name:20s} final cumulative = {cum:6.2f}s  layouts: "
          f"{[r.layout.describe() for r in store.video('v').store.sots[:6]]}"
          "...")

base = results["not_tiled"]
print("\ncumulative cost normalized to not_tiled (paper Fig. 11d):")
for name, series in results.items():
    pts = [f"{100 * series[i] / base[i]:5.0f}%" for i in
           (9, N_QUERIES // 2, N_QUERIES - 1)]
    print(f"  {name:20s} @q10/q{N_QUERIES//2}/q{N_QUERIES}: {' '.join(pts)}")

# --- background tuning: the same regret workload, re-tiling off the scan
# path.  Queries only *observe*; the tuner thread replays the workload log,
# coalesces proposals, and applies retiles through the durable epoch-bumping
# path.  drain_tuner() after each query is the deterministic barrier that
# keeps the tuning cadence identical to inline — so the layouts converge
# identically while ScanStats.retile_s stays 0 for every query.
print("\nbackground tuner (tuning='background', RegretPolicy):")
bg = make_store(RegretPolicy, "background")
bg.ingest("v", frames)
worst_ms, charged = 0.0, 0
for label, t_range in queries:
    st = bg.scan("v").labels(label).frames(*t_range).execute().stats
    worst_ms = max(worst_ms, 1e3 * (st.decode_s + st.lookup_s + st.retile_s))
    charged += st.retile_s > 0
    bg.drain_tuner()          # barrier, OUTSIDE the query's critical path
ts = bg.tuner_stats()
print(f"  queries charged retile time: {charged}/{N_QUERIES} "
      f"(worst query {worst_ms:.0f} ms pays decode+lookup only)")
print(f"  tuner: {ts.observed} observations -> {ts.proposals} proposals, "
      f"{ts.coalesced} coalesced, {ts.applied} applied "
      f"({ts.retile_s:.2f}s re-encode off the scan path)")
inline_layouts = [r.layout.describe()
                  for r in store.video("v").store.sots]
bg_layouts = [r.layout.describe() for r in bg.video("v").store.sots]
print(f"  converged to the same layouts as inline: "
      f"{bg_layouts == inline_layouts}")
bg.close()
