"""End-to-end driver (deliverable b): train a ~100M-param LM for a few
hundred steps with the full production stack — TASM-fed data pipeline,
straggler-tolerant prefetch, fault-tolerant checkpointing with a simulated
node failure, AdamW, and recovery.

    PYTHONPATH=src python examples/train_video_lm.py --steps 300

The model is smollm-135m at published size when --full is passed; the
default trims layers so a few hundred steps fit CPU CI time while keeping
the exact family (the 512-chip shapes are exercised by the dry-run).
"""
import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import zoo
from repro.train.checkpoint import CheckpointManager
from repro.train.data import PrefetchPipeline, synthetic_token_batches
from repro.train.elastic import LoopConfig, recoverable_train_loop
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="published smollm-135m size (slow on CPU)")
    ap.add_argument("--fail-at", type=int, default=120,
                    help="simulate a node failure at this step (0=off)")
    args = ap.parse_args()

    cfg = get_config("smollm-135m")
    if not args.full:
        cfg = dataclasses.replace(cfg, n_layers=4, d_model=256, n_heads=8,
                                  n_kv_heads=4, head_dim=32, d_ff=1024,
                                  vocab=8192, loss_chunk=2048)
    n = cfg.param_count()
    print(f"model: {cfg.name}  params={n / 1e6:.1f}M  layers={cfg.n_layers}")

    params = zoo.init_model(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    raw_step = jax.jit(make_train_step(cfg, opt_cfg))

    def step_fn(state, batch):
        params, opt = state
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = raw_step(params, opt, batch)
        return (params, opt), metrics

    source = synthetic_token_batches(cfg.vocab, args.batch, args.seq,
                                     n_batches=args.steps * 2)
    pipe = PrefetchPipeline(source, depth=4, deadline_s=5.0)

    faults = {"armed": args.fail_at > 0}

    def fault_hook(step):
        if faults["armed"] and step == args.fail_at:
            faults["armed"] = False
            raise RuntimeError("simulated node failure")

    losses = []

    def on_metrics(step, m):
        losses.append(float(m["loss"]))
        if step % 25 == 0:
            print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                  f"lr {float(m.get('lr', 0)):.2e}")

    with tempfile.TemporaryDirectory() as ckdir:
        ckpt = CheckpointManager(ckdir, keep=2)
        t0 = time.time()
        (params, opt), steps, restarts = recoverable_train_loop(
            (params, opt), pipe, step_fn, ckpt=ckpt,
            cfg=LoopConfig(total_steps=args.steps, checkpoint_every=50),
            fault_hook=fault_hook, on_metrics=on_metrics)
        dt = time.time() - t0

    print(f"\ndone: {steps} steps in {dt:.1f}s "
          f"({args.batch * args.seq * steps / dt:.0f} tok/s), "
          f"restarts={restarts}, prefetch stats={pipe.stats}")
    print(f"loss: first={losses[0]:.3f} last={np.mean(losses[-10:]):.3f}")
    assert np.mean(losses[-10:]) < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
