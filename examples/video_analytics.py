"""Full paper pipeline (Fig. 2): the VideoStore engine feeds pixel regions
to an analytics model (the VLM family from the assigned pool, reduced) — the
query processor writes its detections back through ADDMETADATA, closing the
loop that the regret policy learns layouts from.

    PYTHONPATH=src python examples/video_analytics.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.codec.encode import EncoderConfig
from repro.configs.base import get_config, reduce_config
from repro.core import RegretPolicy, VideoStore
from repro.core.calibrate import calibrated_cost_model
from repro.data.video_gen import generate, sparse_spec
from repro.models import zoo
from repro.train.data import tasm_region_batches

ENC = EncoderConfig(gop=16, qp=8)

# --- storage layer: VideoStore engine with incremental tiling ------------
spec = sparse_spec(seed=4, n_frames=96)
frames, dets = generate(spec)
model = calibrated_cost_model(ENC, seeds=(0,), repeats=1)
store = VideoStore()
store.add_video("cam0", encoder=ENC, policy=RegretPolicy(),
                cost_model=model)
store.ingest("cam0", frames)
store.add_detections("cam0", {f: d for f, d in enumerate(dets)})

# --- analytics model: internvl2-family backbone (reduced) ----------------
cfg = reduce_config(get_config("internvl2-26b"))
params = zoo.init_model(cfg, jax.random.key(0))
print(f"analytics backbone: {cfg.name} ({cfg.param_count() / 1e3:.0f}K params)")

# the engine streams decoded object crops; the 'frontend stub' turns each
# crop into patch embeddings for the backbone
batches = tasm_region_batches(store, ["car", "person"], batch=4, crop=16,
                              video="cam0")


@jax.jit
def score(params, pixels, tokens):
    # crops -> fake patch embeddings (frontend stub), then backbone forward
    B = pixels.shape[0]
    pe = pixels.reshape(B, -1)[:, : cfg.frontend_tokens * cfg.frontend_dim]
    need = cfg.frontend_tokens * cfg.frontend_dim
    pe = jnp.pad(pe, ((0, 0), (0, max(0, need - pe.shape[1]))))
    pe = pe.reshape(B, cfg.frontend_tokens, cfg.frontend_dim) / 255.0
    batch = {"patch_embeds": pe, "tokens": tokens}
    h = zoo.forward(params, cfg, batch, remat=False)
    return zoo.logits_fn(params, cfg, h[:, -1:])


for i in range(3):
    b = next(batches)
    tokens = jnp.zeros((b["pixels"].shape[0], 8), jnp.int32)
    logits = score(params, jnp.asarray(b["pixels"]), tokens)
    print(f"batch {i}: crops {b['pixels'].shape} labels {b['labels']} "
          f"-> logits {logits.shape}, finite={bool(np.isfinite(np.asarray(logits)).all())}")

store.drain_tuner()  # let the background tuner apply pending re-tiles
print("layouts after analytics queries:",
      [r.layout.describe() for r in store.video("cam0").store.sots])
print("per-query history (decode ms / cache h:m):",
      [f"{s.decode_s * 1e3:.0f} {s.cache_hits}:{s.cache_misses}"
       for s in store.video("cam0").history[-8:]])
