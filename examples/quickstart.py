"""Quickstart: ingest a video into TASM, run object queries, watch the
storage manager adapt its tile layout (paper §1's amber-alert flow).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.codec.encode import EncoderConfig
from repro.core import TASM, RegretPolicy
from repro.core.calibrate import calibrated_cost_model
from repro.data.video_gen import generate, sparse_spec

# 1. a "camera feed": procedural traffic video with ground-truth detections
spec = sparse_spec(seed=0, n_frames=128, height=192, width=320)
frames, detections = generate(spec)
print(f"video: {frames.shape}, objects: "
      f"{sorted({l for d in detections for l, _ in d})}")

# 2. TASM with the regret-based incremental tiling policy (§4.4)
model = calibrated_cost_model(EncoderConfig(), seeds=(0,), repeats=1)
tasm = TASM("traffic", EncoderConfig(gop=16, qp=8),
            policy=RegretPolicy(), cost_model=model)
tasm.ingest(frames)
print(f"ingested untiled: {tasm.storage_bytes() / 1e3:.0f} KB")

# 3. the query processor detects objects as a byproduct of queries and feeds
#    the semantic index via ADDMETADATA
for f, dets in enumerate(detections):
    for label, (y1, x1, y2, x2) in dets:
        tasm.add_metadata("traffic", f, label, x1, y1, x2, y2)
print("semantic index:", tasm.index.stats())

# 4. issue repeated SCAN(video, L, T) queries; the layout evolves
for i in range(14):
    res = tasm.scan("car", (0, 64))
    s = res.stats
    print(f"q{i}: decode={s.decode_s * 1e3:6.1f} ms  "
          f"pixels={s.pixels_decoded / 1e6:5.2f} M  tiles={s.tiles_decoded:3.0f}"
          f"  retile={s.retile_s * 1e3:6.1f} ms")

print("final layouts:", [r.layout.describe() for r in tasm.store.sots])

# 5. a CNF query: red AND car would intersect label boxes; here: car OR person
res = tasm.scan(["car", "person"], (0, 32))
print(f"disjunctive query returned {len(res.regions)} regions")

# 6. verify pixels: the decoded crop matches the source (lossy codec)
f, box, px = res.regions[0]
y1, x1, y2, x2 = box
err = np.abs(px - frames[f, y1:y2, x1:x2]).mean()
print(f"mean |decoded - source| = {err:.2f} (8-bit scale)")
