"""Quickstart: ingest a camera feed into the VideoStore engine, run
declarative scan queries, watch the storage manager adapt its tile layout
(paper §1's amber-alert flow) — and reopen the catalog from its manifest.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import numpy as np

from repro.codec.encode import EncoderConfig
from repro.core import RegretPolicy, VideoStore
from repro.core.calibrate import calibrated_cost_model
from repro.data.video_gen import generate, sparse_spec

# 1. a "camera feed": procedural traffic video with ground-truth detections
spec = sparse_spec(seed=0, n_frames=128, height=192, width=320)
frames, detections = generate(spec)
print(f"video: {frames.shape}, objects: "
      f"{sorted({l for d in detections for l, _ in d})}")

# 2. a VideoStore catalog backed by disk, with the regret-based incremental
#    tiling policy (§4.4) for this camera
root = tempfile.mkdtemp(prefix="tasm_store_")
model = calibrated_cost_model(EncoderConfig(), seeds=(0,), repeats=1)
store = VideoStore(store_root=root)
store.add_video("traffic", encoder=EncoderConfig(gop=16, qp=8),
                policy=RegretPolicy(), cost_model=model)
store.ingest("traffic", frames)
print(f"ingested untiled: {store.storage_bytes('traffic') / 1e3:.0f} KB "
      f"-> catalog at {store.catalog_path}")

# 3. the query processor detects objects as a byproduct of queries and feeds
#    the semantic index via ADDMETADATA
for f, dets in enumerate(detections):
    for label, (y1, x1, y2, x2) in dets:
        store.add_metadata("traffic", f, label, x1, y1, x2, y2)
print("semantic index:", store.video("traffic").index.stats())

# 4. plan/execute split: EXPLAIN shows the SOTs/tiles the engine would
#    decode, with estimated cost from the what-if interface — no decoding
query = store.scan("traffic").labels("car").frames(0, 64)
print("\n" + query.explain().describe() + "\n")

# 5. ROI-restricted block decode (the default): a subframe scan decodes
#    only the 8x8 blocks its boxes intersect, so pixels_decoded tracks the
#    *requested* pixels, not tile area.  Toggle it off to see what the same
#    query costs under full-tile decode — results are bit-identical
store.roi_decode = False
full_px = query.execute().stats.pixels_decoded
store.tile_cache.clear()   # cold again, so the ROI run really decodes
store.roi_decode = True
roi_px = query.execute().stats.pixels_decoded
print(f"pixels decoded, full-tile {full_px / 1e6:.2f} M -> "
      f"ROI {roi_px / 1e6:.2f} M ({full_px / max(roi_px, 1):.1f}x fewer)")

# 5b. batched fused decode: VideoStore(decode=DecodeConfig(
#     backend="batched")) (or env REPRO_DECODE_BACKEND=batched, or
#     --decode-backend on tasm_serve.py) flattens every (tile, GOP,
#     block-mask) selection of a group fetch into one fused
#     dequant+IDCT+cumsum dispatch — Pallas on TPU, jitted XLA elsewhere —
#     instead of the per-tile numpy loop.  Results and decode counters are
#     bit-identical; fine-tiled merged batches decode 1.5-5x faster (see
#     BENCH_decode_kernel.json)
from repro.core import DecodeConfig

batched = VideoStore(decode=DecodeConfig(backend="batched"))
batched.add_video("traffic", encoder=EncoderConfig(gop=16, qp=8))
batched.ingest("traffic", frames)
batched.add_detections("traffic", {f: d for f, d in enumerate(detections)})
r_batched = batched.scan("traffic").labels("car").frames(0, 64).execute()
r_numpy = query.execute()
same = all(a[:-1] == b[:-1] and np.array_equal(a[-1], b[-1])
           for a, b in zip(r_numpy.regions, r_batched.regions))
print(f"batched decode backend: {len(r_batched.regions)} regions, "
      f"bit-identical to numpy: {same}")
batched.close()

# 6. issue repeated declarative queries; the layout evolves under the policy
#    and the tile cache absorbs repeat decodes (epoch bumps invalidate it).
#    Tuning runs in the BACKGROUND by default: queries only emit workload
#    observations, the tuner thread re-tiles off the critical path, so
#    retile stays 0.0 ms for every query (pass tuning="inline" to get the
#    old synchronous behaviour)
for i in range(14):
    s = query.execute().stats
    print(f"q{i}: decode={s.decode_s * 1e3:6.1f} ms  "
          f"pixels={s.pixels_decoded / 1e6:5.2f} M  tiles={s.tiles_decoded:3.0f}"
          f"  cache={s.cache_hits}h/{s.cache_misses}m"
          f"  retile={s.retile_s * 1e3:6.1f} ms")

ts = store.drain_tuner()  # barrier: wait for background tuning to settle
print(f"tuner: {ts.observed} observations -> {ts.applied} retiles applied, "
      f"{ts.retile_s * 1e3:.0f} ms re-encode paid off the scan path")
print("final layouts:",
      [r.layout.describe() for r in store.video("traffic").store.sots])
print("\nafter adaptation:\n" + query.explain().describe())

# 6b. workload-predictive tile cache: the cache knobs now live on ONE
#     CacheConfig — byte budget, eviction ("reuse" weights entries by how
#     often they were re-accessed, "lru" is the legacy order), block
#     packing (ROI entries store only their 8x8 blocks, not a zero-padded
#     canvas), and prefetch.  The old VideoStore(tile_cache_bytes=...)
#     kwarg still works for one release as a deprecated alias.  With
#     prefetch on, the cache taps the tuner's workload log: after three
#     windows of a sliding scan it recognizes the monotone SOT progression
#     and decodes the NEXT SOTs on the worker pool before they are asked
#     for — later windows then decode zero tiles
from repro.core import CacheConfig

pred = VideoStore(cache=CacheConfig(prefetch=True, prefetch_depth=2))
pred.add_video("traffic", encoder=EncoderConfig(gop=16, qp=8), sot_len=16)
pred.ingest("traffic", frames)
pred.add_detections("traffic", {f: d for f, d in enumerate(detections)})
print()
for i in range(8):
    s = pred.scan("traffic").labels("car") \
            .frames(i * 16, (i + 1) * 16).execute().stats
    pred.drain_prefetch()  # barrier: the demo stays deterministic
    print(f"window {i}: pixels={s.pixels_decoded / 1e6:5.2f} M  "
          f"cache={s.cache_hits}h/{s.cache_misses}m")
cs = pred.tile_cache.stats()
print(f"prefetch: {cs.prefetch_issued} issued, {cs.prefetch_hits} hit, "
      f"{cs.prefetch_wasted} wasted; block packing saved "
      f"{cs.packed_bytes_saved / 1e6:.1f} MB of cache budget")
pred.close()

# 7. disjunctive predicate (one clause: car OR person), limited
res = store.scan("traffic").labels("car", "person").frames(0, 32) \
           .limit(50).execute()
print(f"\ndisjunctive query returned {len(res.regions)} regions (limit 50)")

# 8. verify pixels: the decoded crop matches the source (lossy codec)
f, box, px = res.regions[0]
y1, x1, y2, x2 = box
err = np.abs(px - frames[f, y1:y2, x1:x2]).mean()
print(f"mean |decoded - source| = {err:.2f} (8-bit scale)")

# 9. concurrent serving: overlapping scans submitted together merge their
#    SOT decodes (each shared tile decoded at most once, then cached)
with store.serve() as session:
    futs = [session.submit(store.scan("traffic").labels("car").frames(0, 64))
            for _ in range(4)]
    batch = [f.result() for f in futs]
hits = sum(r.stats.cache_hits for r in batch)
misses = sum(r.stats.cache_misses for r in batch)
print(f"\nserved 4 overlapping scans: {hits} cache hits, "
      f"{misses} fresh tile decodes")

# 10. reopen the catalog from its on-disk manifest: no re-ingest needed
reopened = VideoStore(store_root=root)
res2 = reopened.scan("traffic").labels("car").frames(0, 64).execute()
same = all(np.array_equal(p1, p2) for (_, _, p1), (_, _, p2)
           in zip(store.scan("traffic").labels("car").frames(0, 64)
                  .execute().regions, res2.regions))
print(f"reopened {reopened.videos()} from manifest; "
      f"scan bit-identical: {same}")

# 11. cross-process serving: expose the store over a socket and query it
#     with RemoteVideoStore — same declarative surface, shared cache, and
#     results bit-identical to in-process execute().  (In production the
#     server runs via `scripts/tasm_serve.py --socket ...` and clients are
#     separate processes; here both ends live in this script.)
import os

from repro.core import RemoteVideoStore, VideoStoreServer

sock = os.path.join(root, "tasm.sock")
with VideoStoreServer(reopened, path=sock, owns_store=False).start():
    with RemoteVideoStore(sock) as remote:
        r_remote = remote.scan("traffic").labels("car").frames(0, 64) \
                         .execute()
        same = all(np.array_equal(a[-1], b[-1])
                   for a, b in zip(res2.regions, r_remote.regions))
        print(f"\nremote scan over {remote.ping()['codec']} wire: "
              f"{len(r_remote.regions)} regions, bit-identical: {same}, "
              f"cache hits {r_remote.stats.cache_hits}")

# 12. distributed VideoStore: two nodes behind a ClusterRouter.  The router
#     places videos by consistent hash (persisted placement map), writes
#     every replica (replication=2 here), routes reads to the primary's
#     warm cache, and fails over if a node dies — all behind the SAME
#     declarative surface, bit-identical to a single store.  (In
#     production the nodes run `scripts/tasm_serve.py` and the router
#     `scripts/tasm_router.py`; here all three live in this script.)
from repro.core import (ClusterClient, ClusterRouter, ClusterRouterServer,
                        NoTilingPolicy)

nodes = {f"n{i}": os.path.join(root, f"node{i}.sock") for i in range(3)}
node_stores = {name: VideoStore() for name in nodes}
node_servers = {name: VideoStoreServer(node_stores[name], path=path,
                                       owns_store=False).start()
                for name, path in nodes.items()}
router = ClusterRouter(nodes, replication=2,
                       placement_path=os.path.join(root, "placement.json"))
router.add_video("traffic", encoder=EncoderConfig(gop=16, qp=8),
                 policy=NoTilingPolicy())
router.ingest("traffic", frames)
router.add_detections("traffic", {f: d for f, d in enumerate(detections)})
rsock = os.path.join(root, "router.sock")
with ClusterRouterServer(router, path=rsock, owns_store=False).start():
    with ClusterClient(rsock) as cluster:
        r_cluster = cluster.scan("traffic").labels("car").frames(0, 64) \
                           .execute()
        ref = VideoStore()
        ref.add_video("traffic", encoder=EncoderConfig(gop=16, qp=8),
                      policy=NoTilingPolicy())
        ref.ingest("traffic", frames)
        ref.add_detections("traffic", {f: d for f, d in enumerate(detections)})
        r_single = ref.scan("traffic").labels("car").frames(0, 64).execute()
        same = all(a[:-1] == b[:-1] and np.array_equal(a[-1], b[-1])
                   for a, b in zip(r_single.regions, r_cluster.regions))
        print(f"\ncluster of {len(nodes)} nodes (replication=2): "
              f"{len(r_cluster.regions)} regions, bit-identical to a "
              f"single store: {same}, placement "
              f"{cluster.placement()['assignments']}")

        # 12b. self-healing: kill the video's primary node for good, then
        #      one repair command re-replicates everything it held onto
        #      the spare node — tiles stream node→node in the background
        #      (checksummed, resumable, committed atomically), reads keep
        #      serving from the surviving replica throughout, and the
        #      placement flips only after the copy verifies.  (From a
        #      shell this is `tasm_router.py --socket ... --repair
        #      node=<name>`; the same RPCs drive it here.)
        victim = cluster.placement()["assignments"]["traffic"][0]
        node_servers.pop(victim).stop()
        node_stores.pop(victim).close()
        r_degraded = cluster.scan("traffic").labels("car").frames(0, 64) \
                            .execute()          # failover, no repair yet
        jobs = cluster.repair(node=victim)
        status = cluster.drain_repair()         # wait for the copy
        r_healed = cluster.scan("traffic").labels("car").frames(0, 64) \
                          .execute()
        same = all(a[:-1] == b[:-1] and np.array_equal(a[-1], b[-1])
                   for a, b in zip(r_single.regions, r_healed.regions))
        print(f"killed {victim} -> {len(r_degraded.regions)} regions via "
              f"failover; repair streamed {len(jobs)} job(s), "
              f"{status['stats']['chunks_copied']} chunks "
              f"({status['stats']['bytes_copied'] / 1e6:.2f} MB); healed "
              f"placement {cluster.placement()['assignments']['traffic']}, "
              f"bit-identical: {same}")
        ref.close()
router.close()
for srv in node_servers.values():
    srv.stop()
for s in node_stores.values():
    s.close()

# 13. zero-copy serving: on a same-host unix socket the server ships
#     result arrays through POSIX shared memory — clients map the pages
#     instead of copying them off the socket (transport="auto" negotiates
#     it; "socket" forces the npz fallback used for TCP/cross-host).
#     Both transports produce bit-identical bytes, and every reply's
#     marshalling cost is stamped into its ScanStats.
from repro.core.shm import shm_available

sock13 = os.path.join(root, "tasm13.sock")
with VideoStoreServer(reopened, path=sock13, owns_store=False).start():
    with RemoteVideoStore(sock13) as fast, \
         RemoteVideoStore(sock13, transport="socket") as slow:
        r_shm = fast.scan("traffic").labels("car").frames(0, 64).execute()
        r_npz = slow.scan("traffic").labels("car").frames(0, 64).execute()
        same = all(a[:-1] == b[:-1] and np.array_equal(a[-1], b[-1])
                   for a, b in zip(r_shm.regions, r_npz.regions))
        print(f"\nzero-copy serving (shm available: {shm_available()}): "
              f"negotiated {fast.transport!r} vs forced {slow.transport!r}, "
              f"bit-identical: {same}; "
              f"{r_shm.stats.payload_bytes} payload bytes marshalled in "
              f"{r_shm.stats.marshal_s * 1e3:.2f} ms over "
              f"{r_shm.stats.transport}")

reopened.close()
store.close()
