"""Batched serving example: prefill a batch of prompts, then decode with the
layer-stacked KV cache — the same serve_step the 512-chip dry-run lowers.

    PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, make_serve_config
from repro.models import zoo
from repro.serve.serve_step import greedy_generate, make_decode_step

cfg = get_config("smollm-135m")
cfg = dataclasses.replace(cfg, n_layers=6, d_model=256, n_heads=8,
                          n_kv_heads=4, head_dim=32, d_ff=1024, vocab=4096)
cfg = make_serve_config(cfg, model_axis=1)
params = zoo.init_model(cfg, jax.random.key(1))
print(f"serving {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
      f"kv_repeat={cfg.kv_repeat}")

# a batch of 8 requests, prompt length 32
B, S0, NEW = 8, 32, 48
prompts = jax.random.randint(jax.random.key(2), (B, S0), 0, cfg.vocab)

t0 = time.time()
out = greedy_generate(params, cfg, prompts, max_new=NEW)
dt = time.time() - t0
print(f"generated {B}x{NEW} tokens in {dt:.2f}s "
      f"({B * NEW / dt:.0f} tok/s incl. prefill + compile)")
print("sample continuation ids:", np.asarray(out[0][:16]))

# steady-state decode throughput (compiled path only)
caches = zoo.init_cache(cfg, B, S0 + NEW + 64)
step = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
tok = out[:, -1:]
logits, caches = step(params, caches, {"tokens": tok}, jnp.int32(S0 + NEW))
jax.block_until_ready(logits)
t0 = time.time()
n = 64
idx = S0 + NEW + 1
for i in range(n):
    logits, caches = step(params, caches,
                          {"tokens": jnp.argmax(logits[:, -1:], -1)},
                          jnp.int32(idx + i))
jax.block_until_ready(logits)
dt = time.time() - t0
print(f"steady-state decode: {n * B / dt:.0f} tok/s "
      f"({dt / n * 1e3:.1f} ms/step at batch {B})")
