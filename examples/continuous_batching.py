"""Iteration-level batched serving with the ContinuousBatcher scheduler:
requests of different lengths share decode steps; early finishers retire
while the wave drains; TTFT/latency/throughput are reported.

    PYTHONPATH=src python examples/continuous_batching.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs.base import get_config, make_serve_config
from repro.models import zoo
from repro.serve.batching import ContinuousBatcher

cfg = get_config("smollm-135m")
cfg = dataclasses.replace(cfg, n_layers=4, d_model=192, n_heads=6,
                          n_kv_heads=3, head_dim=32, d_ff=512, vocab=2048)
cfg = make_serve_config(cfg, model_axis=1)
params = zoo.init_model(cfg, jax.random.key(0))

batcher = ContinuousBatcher(cfg, params, slots=4, max_len=128)
rng = np.random.default_rng(0)
for i in range(10):
    plen = int(rng.integers(8, 24))
    batcher.submit(rng.integers(0, cfg.vocab, plen).astype(np.int32),
                   max_new=int(rng.integers(8, 20)))

stats = batcher.run_until_drained()
print("served:", stats)
assert stats["requests"] == 10
for r in batcher.finished[:3]:
    print(f"  req {r.rid}: prompt {len(r.prompt)} -> {len(r.out_tokens)} new "
          f"tokens, ttft {1e3 * (r.first_token_at - r.submitted_at):.0f} ms")
