"""Edge tiling (paper §4.3): the camera detects objects as frames are
captured — full YOLO every k frames (an edge GPU can't run every frame) —
and the video arrives at the VDBMS already tiled around O_Q, with the
semantic index pre-initialized.  Compare against bgsub- and tiny-detector
edge configurations (§5.2.4).

    PYTHONPATH=src python examples/edge_tiling.py
"""
import numpy as np

from repro.codec.encode import EncoderConfig
from repro.core import TASM, NoTilingPolicy
from repro.core.calibrate import calibrated_cost_model
from repro.core.detector import DetectorConfig, detect
from repro.core.layout import partition
from repro.data.video_gen import generate, sparse_spec

ENC = EncoderConfig(gop=16, qp=8)
spec = sparse_spec(seed=2, n_frames=128)
frames, gt = generate(spec)
H, W = frames.shape[1:]
model = calibrated_cost_model(ENC, seeds=(0,), repeats=1)
O_Q = ["car"]  # the VDBMS tells the camera which objects queries will target


def edge_ingest(det_cfg: DetectorConfig, name: str):
    found, det_secs = detect(frames, gt, det_cfg)
    # the camera designs PARTITION(v, O_Q) layouts per GOP at capture time
    layouts = {}
    for g in range(len(frames) // ENC.gop):
        boxes = [b for f in range(g * ENC.gop, (g + 1) * ENC.gop)
                 for l, b in found.get(f, []) if l in O_Q or l == "object"]
        if boxes:
            layouts[g] = partition(H, W, boxes)
    tasm = TASM(name, ENC, policy=NoTilingPolicy(), cost_model=model)
    tasm.add_detections(found)          # pre-initialized semantic index
    tasm.ingest(frames, initial_layouts=layouts)
    # ground truth boxes are what queries ultimately retrieve
    tasm.add_detections({f: d for f, d in enumerate(gt)})
    secs = 0.0
    for _ in range(6):
        st = tasm.scan("car", (0, 64)).stats
        secs += st.decode_s + st.lookup_s
    return det_secs, secs, layouts


# baseline: cloud ingest, no tiles
base = TASM("untiled", ENC, cost_model=model)
base.ingest(frames)
base.add_detections({f: d for f, d in enumerate(gt)})
base_secs = sum((base.scan("car", (0, 64)).stats.decode_s
                 + base.scan("car", (0, 64)).stats.lookup_s) for _ in range(3))

print(f"{'edge detector':28s} {'on-camera s':>12s} {'6-query decode s':>17s}")
for name, cfg in [
    ("full YOLO every frame", DetectorConfig(kind="full")),
    ("full YOLO every 5 frames", DetectorConfig(kind="strided", stride=5)),
    ("tiny YOLO (misses ~50%)", DetectorConfig(kind="tiny")),
    ("background subtraction", DetectorConfig(kind="bgsub")),
]:
    det_secs, q_secs, layouts = edge_ingest(cfg, name.replace(" ", "_"))
    print(f"{name:28s} {det_secs:12.2f} {q_secs:17.3f}   "
          f"({len(layouts)} GOPs pre-tiled)")
print(f"{'(untiled cloud ingest)':28s} {'-':>12s} {base_secs * 2:17.3f}")
