"""Edge tiling (paper §4.3): the camera detects objects as frames are
captured — full YOLO every k frames (an edge GPU can't run every frame) —
and the video arrives at the VDBMS already tiled around O_Q, with the
semantic index pre-initialized.  Compare against bgsub- and tiny-detector
edge configurations (§5.2.4).  Each configuration is one video in a single
VideoStore catalog, so one engine serves them all.

    PYTHONPATH=src python examples/edge_tiling.py
"""
import numpy as np

from repro.codec.encode import EncoderConfig
from repro.core import CacheConfig, NoTilingPolicy, VideoStore
from repro.core.calibrate import calibrated_cost_model
from repro.core.detector import DetectorConfig, detect
from repro.core.layout import partition
from repro.data.video_gen import generate, sparse_spec

ENC = EncoderConfig(gop=16, qp=8)
spec = sparse_spec(seed=2, n_frames=128)
frames, gt = generate(spec)
H, W = frames.shape[1:]
model = calibrated_cost_model(ENC, seeds=(0,), repeats=1)
O_Q = ["car"]  # the VDBMS tells the camera which objects queries will target

# cache off: this example compares repeat-decode cost across edge layouts
store = VideoStore(default_encoder=ENC, default_cost_model=model,
                   default_policy=NoTilingPolicy(), cache=CacheConfig(budget_bytes=0))


def edge_ingest(det_cfg: DetectorConfig, name: str):
    found, det_secs = detect(frames, gt, det_cfg)
    # the camera designs PARTITION(v, O_Q) layouts per GOP at capture time
    layouts = {}
    for g in range(len(frames) // ENC.gop):
        boxes = [b for f in range(g * ENC.gop, (g + 1) * ENC.gop)
                 for l, b in found.get(f, []) if l in O_Q or l == "object"]
        if boxes:
            layouts[g] = partition(H, W, boxes)
    store.add_video(name)
    store.add_detections(name, found)   # pre-initialized semantic index
    store.ingest(name, frames, initial_layouts=layouts)
    # ground truth boxes are what queries ultimately retrieve
    store.add_detections(name, {f: d for f, d in enumerate(gt)})
    secs = 0.0
    for _ in range(6):
        st = store.scan(name).labels("car").frames(0, 64).execute().stats
        secs += st.decode_s + st.lookup_s
    return det_secs, secs, layouts


# baseline: cloud ingest, no tiles — just another catalog entry
store.add_video("untiled")
store.ingest("untiled", frames)
store.add_detections("untiled", {f: d for f, d in enumerate(gt)})
base_q = store.scan("untiled").labels("car").frames(0, 64)
base_secs = sum((base_q.execute().stats.decode_s
                 + base_q.execute().stats.lookup_s) for _ in range(3))

print(f"{'edge detector':28s} {'on-camera s':>12s} {'6-query decode s':>17s}")
for name, cfg in [
    ("full YOLO every frame", DetectorConfig(kind="full")),
    ("full YOLO every 5 frames", DetectorConfig(kind="strided", stride=5)),
    ("tiny YOLO (misses ~50%)", DetectorConfig(kind="tiny")),
    ("background subtraction", DetectorConfig(kind="bgsub")),
]:
    det_secs, q_secs, layouts = edge_ingest(cfg, name.replace(" ", "_"))
    print(f"{name:28s} {det_secs:12.2f} {q_secs:17.3f}   "
          f"({len(layouts)} GOPs pre-tiled)")
print(f"{'(untiled cloud ingest)':28s} {'-':>12s} {base_secs * 2:17.3f}")
print(f"\ncatalog now holds {len(store)} videos: {store.videos()}")
plan = store.scan(store.videos()).labels("car").frames(0, 16).explain()
print(f"one cross-video plan touches {len(plan.sot_scans)} SOTs, "
      f"est {plan.est_cost_s * 1e3:.1f} ms")
