"""Self-healing benchmark: kill one node of a K=2 cluster mid-workload,
run background repair, and measure what the serving path notices —
emitting ``BENCH_repair.json``.

The claim under test is the repair plane's contract: after a permanent
node loss, ``ClusterRouter.repair(node=...)`` restores the replication
factor by streaming tiles node→node OFF the serving path — reads keep
flowing (zero failures), every wave of the workload stays bit-identical
to a single in-process store, and the placement flip lands only after
per-tile checksums and the epoch table verify on the rebuilt replica.

Hard gates (CI fails if self-healing breaks):
- every repair job completes and replication is restored: the dead node
  leaves every assignment, every video is back to K=2 replicas;
- zero failed reads across every wave — before the kill, during the
  background copy, and after the flip;
- every wave (idle, degraded, during-repair, post-repair) is
  bit-identical to the single-store reference digest;
- the rebuilt replica holds the full expected epoch table.

Latency impact is reported: per-query p95 during the background copy vs
idle.  The gate (p95 during repair <= 5x idle p95) is soft in quick mode
(single-sample wall clock on a shared runner) and hard in full runs —
the data plane must not head-of-line-block scans.

    PYTHONPATH=src:. python benchmarks/fig_repair.py               # full
    REPRO_QUICK=1 PYTHONPATH=src:. python benchmarks/fig_repair.py # smoke
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import tempfile
import time

import numpy as np

from benchmarks.common import ENC, corpus_video, emit, gate, quick_mode

QUICK = quick_mode()
N_NODES = 3
REPLICATION = 2
N_VIDEOS = 8
N_FRAMES = 32 if QUICK else 64
H, W = 96, 160
OUT = os.environ.get("REPRO_BENCH_OUT", "BENCH_repair.json")

VIDEOS = [f"cam{i:02d}" for i in range(N_VIDEOS)]


def corpus():
    return {v: corpus_video("sparse", i, N_FRAMES, height=H, width=W)[:2]
            for i, v in enumerate(VIDEOS)}


def seed(store, videos: dict) -> None:
    from repro.core import NoTilingPolicy

    for name, (frames, dets) in videos.items():
        store.add_video(name, encoder=ENC, policy=NoTilingPolicy())
        store.ingest(name, frames)
        store.add_detections(name, {f: d for f, d in enumerate(dets)})


def workload(store) -> list:
    """Two scans per video: full-range car + an offset person window."""
    qs = []
    for i, v in enumerate(VIDEOS):
        qs.append(store.scan(v).labels("car").frames(0, N_FRAMES))
        lo = (i * ENC.gop) % (N_FRAMES - ENC.gop)
        qs.append(store.scan(v).labels("person").frames(lo, lo + ENC.gop))
    return qs


def digest(results) -> str:
    h = hashlib.sha256()
    for r in results:
        for reg in r.regions:
            *key, px = reg
            h.update(repr((tuple(key), px.shape, str(px.dtype))).encode())
            h.update(np.ascontiguousarray(px).tobytes())
    return h.hexdigest()


def run_wave(store, lats: list, failures: list) -> str:
    """One pass over the workload, one query at a time (per-query
    latency), never letting an exception kill the wave — failed reads
    are counted and gated to zero."""
    results = []
    for q in workload(store):
        t0 = time.perf_counter()
        try:
            results.append(q.execute())
        except Exception as e:  # noqa: BLE001 - a failed read is the gate
            failures.append(f"{type(e).__name__}: {e}")
            continue
        lats.append(time.perf_counter() - t0)
    return digest(results)


def p95(lats: list) -> float:
    return float(np.percentile(np.asarray(lats), 95)) if lats else 0.0


def main() -> None:
    from repro.core import ClusterRouter, VideoStore, VideoStoreServer

    videos = corpus()
    tmp = tempfile.mkdtemp(prefix="tasm_fig_repair_")
    report: dict = {"n_nodes": N_NODES, "n_videos": N_VIDEOS,
                    "replication": REPLICATION, "n_frames": N_FRAMES}

    ref = VideoStore()
    seed(ref, videos)
    ref_digest = digest([q.execute() for q in workload(ref)])
    ref.close()

    stores = {f"n{i}": VideoStore() for i in range(N_NODES)}
    servers = {n: VideoStoreServer(s, path=os.path.join(tmp, f"{n}.sock"),
                                   owns_store=False).start()
               for n, s in stores.items()}
    router = ClusterRouter(
        {n: os.path.join(tmp, f"{n}.sock") for n in stores},
        replication=REPLICATION, timeout=60.0,
        placement_path=os.path.join(tmp, "placement.json"))
    failures: list = []
    try:
        seed(router, videos)

        # -- idle baseline ------------------------------------------------
        idle_lats: list = []
        idle_digests = {run_wave(router, idle_lats, failures)
                        for _ in range(2 if QUICK else 3)}
        gate(idle_digests == {ref_digest},
             "idle cluster waves diverge from the single store")
        report["idle"] = {"p95_ms": 1e3 * p95(idle_lats),
                          "queries": len(idle_lats)}

        # -- kill one node of K=2 mid-workload ----------------------------
        primaries = {n: 0 for n in stores}
        for reps in router.placement.assignments.values():
            primaries[reps[0]] += 1
        victim = max(primaries, key=lambda n: primaries[n])
        report["victim"] = victim
        report["victim_primaries"] = primaries[victim]
        servers.pop(victim).stop()
        stores.pop(victim).close()

        degraded_lats: list = []
        got = run_wave(router, degraded_lats, failures)
        gate(got == ref_digest,
             "degraded wave (node dead, pre-repair) diverges")

        # -- background repair, workload still running --------------------
        jobs = router.repair(node=victim)
        report["jobs_enqueued"] = len(jobs)
        gate(len(jobs) > 0, f"nothing to repair after killing {victim} "
             f"({primaries[victim]} primaries)")
        during_lats: list = []
        waves = 0
        while True:
            got = run_wave(router, during_lats, failures)
            waves += 1
            gate(got == ref_digest,
                 f"wave {waves} during repair diverges")
            status = router.repair_status()
            settled = all(j["status"] in ("done", "failed")
                          for j in status["jobs"])
            if settled and waves >= 2:
                break
        t0 = time.perf_counter()
        status = router.drain_repair(timeout=600)
        report["drain_wait_s"] = time.perf_counter() - t0
        report["during"] = {"p95_ms": 1e3 * p95(during_lats),
                            "queries": len(during_lats), "waves": waves}

        # -- hard gates: healed, bit-identical, zero failed reads ---------
        gate(all(j["status"] == "done" for j in status["jobs"]),
             f"repair jobs failed: {status['jobs']}")
        for v, reps in router.placement.assignments.items():
            gate(victim not in reps and len(reps) == REPLICATION,
                 f"replication not restored for {v}: {reps}")
        post_lats: list = []
        got = run_wave(router, post_lats, failures)
        gate(got == ref_digest, "post-repair wave diverges")
        gate(not failures, f"{len(failures)} failed reads: {failures[:3]}")
        report["failed_reads"] = len(failures)
        report["repair"] = {
            "chunks": status["stats"]["chunks_copied"],
            "bytes": status["stats"]["bytes_copied"],
            "retries": status["stats"]["retries"],
            "copy_s": status["stats"]["copy_s"],
        }

        # -- latency impact: off the serving path means bounded p95 -------
        ratio = report["during"]["p95_ms"] / max(report["idle"]["p95_ms"],
                                                 1e-9)
        report["p95_during_over_idle"] = ratio
        gate(ratio <= 5.0,
             f"repair head-of-line-blocks scans: during p95 "
             f"{report['during']['p95_ms']:.1f}ms vs idle "
             f"{report['idle']['p95_ms']:.1f}ms ({ratio:.2f}x > 5x)",
             hard=not QUICK)
    finally:
        router.close()
        for srv in servers.values():
            srv.stop()
        for s in stores.values():
            s.close()

    pathlib.Path(OUT).write_text(json.dumps(report, indent=1))
    emit("repair_idle", 1e6 * p95(idle_lats),
         f"p95_ms={report['idle']['p95_ms']:.2f}")
    emit("repair_during", 1e6 * p95(during_lats),
         f"p95_ms={report['during']['p95_ms']:.2f};"
         f"ratio={report['p95_during_over_idle']:.2f}x")
    emit("repair_copy", 1e6 * report["repair"]["copy_s"],
         f"chunks={report['repair']['chunks']};"
         f"MB={report['repair']['bytes'] / 1e6:.1f}")
    print(f"# wrote {OUT}: killed {report['victim']} "
          f"({report['victim_primaries']} primaries), "
          f"{report['jobs_enqueued']} jobs, "
          f"{report['repair']['chunks']} chunks "
          f"{report['repair']['bytes'] / 1e6:.1f} MB copied in "
          f"{report['repair']['copy_s']:.2f}s, p95 during/idle "
          f"{report['p95_during_over_idle']:.2f}x, failed reads 0")


if __name__ == "__main__":
    main()
