"""Fig. 12: cumulative cost INCLUDING detection for each strategy.

Strategies (as in the paper):
- pretile_detect_full : YOLO-grade detection over the whole video upfront,
  pre-tile around all objects, then regret-based incremental retiling.
- pretile_bgsub       : cheap background-subtraction upfront; its (poor)
  foreground boxes drive the initial layouts only — queries still need real
  object boxes, found by lazy full detection at query time (+regret).
- incremental_regret  : no upfront work; lazy detection + regret.

Paper claims: the upfront detection cost does not amortize even after 200
queries, motivating edge-side detection.  Scale adaptation: our videos are
~768 frames (vs 12-minute 2K videos), so query starts follow the Zipf
distribution to keep the queried fraction of the video partial — the regime
where lazy detection pays (documented in DESIGN.md §6).
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import ENC, corpus_video, emit, shared_cost_model
from benchmarks.fig11_workloads import _zipf_starts
from repro.core import (CacheConfig, DecodeConfig, PretileAllPolicy,
                        RegretPolicy, TuningConfig, VideoStore)
from repro.core.layout import partition
from repro.core.detector import DetectorConfig, detect

QUICK = bool(int(os.environ.get("REPRO_QUICK", "0")))
N_FRAMES = 384 if QUICK else 768
N_QUERIES = 40 if QUICK else 200
WINDOW = 16


def _queries(rng, n_frames):
    starts = _zipf_starts(rng, N_QUERIES, n_frames - WINDOW)
    labels = rng.choice(["car", "person"], N_QUERIES)
    return [(l, (int(s), int(s) + WINDOW)) for l, s in zip(labels, starts)]


def run():
    model = shared_cost_model()
    rng = np.random.default_rng(7)
    frames, dets, _ = corpus_video("sparse", 0, N_FRAMES)
    H, W = frames.shape[1:]
    queries = _queries(rng, N_FRAMES)
    full_cfg = DetectorConfig(kind="full")

    def run_one(name: str):
        # cache disabled: decode cost per layout is the measured quantity;
        # inline tuning: re-tiling is charged to the triggering query;
        # ROI decode off: the figure models a full-tile decoder (see fig11)
        store = VideoStore(cache=CacheConfig(budget_bytes=0),
                           tuning=TuningConfig(mode="inline"),
                           decode=DecodeConfig(roi=False))
        entry = store.add_video("v", encoder=ENC, policy=RegretPolicy(),
                                cost_model=model)
        upfront = 0.0
        initial_layouts = None
        if name == "pretile_detect_full":
            found, secs = detect(frames, dets, full_cfg)
            store.add_detections("v", found)
            upfront += secs
        elif name == "pretile_bgsub":
            found, secs = detect(frames, dets, DetectorConfig(kind="bgsub"))
            upfront += secs
            # bgsub boxes drive LAYOUTS only (labels are just "object");
            # edge-delivered layouts are free at ingest (pretile_s == 0)
            initial_layouts = {}
            for rec_id in range(N_FRAMES // ENC.gop):
                lo, hi = rec_id * ENC.gop, (rec_id + 1) * ENC.gop
                boxes = [b for f in range(lo, hi)
                         for _, b in found.get(f, [])]
                if boxes:
                    initial_layouts[rec_id] = partition(H, W, boxes)
        if name == "pretile_detect_full":
            entry.policy = PretileAllPolicy()
            upfront += store.ingest("v", frames).pretile_s
            entry.policy = RegretPolicy()
        else:
            upfront += store.ingest(
                "v", frames, initial_layouts=initial_layouts).pretile_s

        detected: set[int] = set()
        if name == "pretile_detect_full":
            detected = set(range(N_FRAMES))
        per_query = [upfront]
        for label, t_range in queries:
            cost = 0.0
            todo = set(range(*t_range)) - detected
            if todo:  # lazy detection at query time (the query processor)
                found, secs = detect(frames, dets, full_cfg,
                                     (min(todo), max(todo) + 1))
                store.add_detections("v", found)
                detected |= set(range(*t_range))
                cost += secs
            res = store.scan("v").labels(label).frames(*t_range).execute()
            cost += res.stats.decode_s + res.stats.lookup_s + res.stats.retile_s
            per_query.append(cost)
        store.close()  # release the decode worker pool
        return np.cumsum(per_query)

    # baseline: untiled, but queries still pay lazy detection (same for all)
    base_store = VideoStore(cache=CacheConfig(budget_bytes=0),
                            decode=DecodeConfig(roi=False))
    base_store.add_video("v", encoder=ENC, cost_model=model)
    base_store.add_detections("v", {f: d for f, d in enumerate(dets)})
    base_store.ingest("v", frames)
    base = [0.0]
    for label, t_range in queries:
        r = base_store.scan("v").labels(label).frames(*t_range).execute()
        base.append(r.stats.decode_s + r.stats.lookup_s)
    base_store.close()  # release the decode worker pool
    base = np.cumsum(base)

    for name in ("pretile_detect_full", "pretile_bgsub", "incremental_regret"):
        cum = run_one(name)
        emit(f"fig12/{name}", 0.0,
             f"final_cum_normalized={100 * cum[-1] / base[-1]:.0f}%;"
             f"upfront_s={cum[0]:.1f}")
    return None


def main():
    run()


if __name__ == "__main__":
    main()
