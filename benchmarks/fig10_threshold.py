"""Fig. 10: the alpha=0.8 not-tiling decision rule.

Scatter of P(v,q,L)/P(v,q,omega) against measured improvement over many
(video, query object, layout) combinations.  Paper claims: thresholding at
0.8 captures nearly all layouts that slow queries down; the few improvements
left of the threshold it sacrifices are small (<20%).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (boxes_for, default_corpus, emit, encode_video,
                               encode_video_per_gop, improvement,
                               per_gop_layouts, query_decode_seconds,
                               query_decode_seconds_per_gop)
from repro.core.layout import single_tile_layout, uniform_layout

ALPHA = 0.8


def run(n_frames: int = 96):
    points = []  # (ratio, improvement)
    for name, frames, dets in default_corpus(n_frames):
        H, W = frames.shape[1:]
        omega = single_tile_layout(H, W)
        enc_o = encode_video(frames, omega)
        labels = sorted({l for d in dets for l, _ in d})
        for q_label in labels:
            bbf = boxes_for(dets, q_label, (0, n_frames))
            if len(bbf) < n_frames // 2:
                continue
            base_s, base_p, _ = query_decode_seconds(enc_o, omega, bbf)
            # candidate layouts: uniform grids + non-uniform around each label
            for r, c in [(2, 2), (3, 3), (4, 6)]:
                lay = uniform_layout(H, W, r, c)
                encs = encode_video(frames, lay)
                s, p, _ = query_decode_seconds(encs, lay, bbf)
                points.append((p / base_p, improvement(base_s, s)))
            for target in labels:
                for gran in ("fine", "coarse"):
                    lays = per_gop_layouts(dets, lambda l, t=target: l == t,
                                           H, W, n_frames, granularity=gran)
                    encs = encode_video_per_gop(frames, lays)
                    s, p, _ = query_decode_seconds_per_gop(encs, lays, bbf)
                    points.append((p / base_p, improvement(base_s, s)))
    pts = np.array(points)
    harmful = pts[pts[:, 1] < 0]
    caught = harmful[harmful[:, 0] > ALPHA]
    missed_good = pts[(pts[:, 0] > ALPHA) & (pts[:, 1] > 0)]
    emit("fig10/points", 0.0, f"n={len(pts)}")
    emit("fig10/harmful_layouts", 0.0,
         f"n={len(harmful)};caught_by_rule={len(caught)}")
    emit("fig10/sacrificed_improvements", 0.0,
         f"n={len(missed_good)};max_sacrificed={missed_good[:,1].max() if len(missed_good) else 0:.1f}%")
    return pts


def main():
    run()


if __name__ == "__main__":
    main()
