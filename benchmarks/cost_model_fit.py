"""Cost-model calibration quality (paper §4.1: R^2 = 0.996 on 1,400 NVDEC
measurements; we re-fit on our codec as the paper prescribes)."""
from __future__ import annotations

from benchmarks.common import emit, gate, quick_mode, shared_cost_model


def run():
    m = shared_cost_model()
    emit("cost_model/beta_s_per_pixel", m.beta * 1e6, f"{m.beta:.3e}")
    emit("cost_model/gamma_s_per_tile", m.gamma * 1e6, f"{m.gamma:.3e}")
    emit("cost_model/r_squared", 0.0, f"{m.r_squared:.4f}")
    emit("cost_model/encode_s_per_pixel", m.encode_per_pixel * 1e6,
         f"{m.encode_per_pixel:.3e}")
    emit("cost_model/io_s_per_pixel", m.io_per_pixel * 1e6,
         f"{m.io_per_pixel:.3e}")
    emit("cost_model/io_r_squared", 0.0, f"{m.io_r_squared:.4f}")
    # The two-term fit quality is the paper's headline (R^2 = 0.996 on
    # NVDEC); the io-term fit covers block-masked decodes whose residual
    # the two-term model misattributes.  Timing-derived, so soft in quick
    # (CI) mode like every other latency gate.
    gate(m.r_squared > 0.9,
         f"beta/gamma fit R^2 {m.r_squared:.4f} <= 0.9",
         hard=not quick_mode())
    gate(m.io_r_squared > 0.5,
         f"io-term fit R^2 {m.io_r_squared:.4f} <= 0.5",
         hard=not quick_mode())
    return m


def main():
    run()


if __name__ == "__main__":
    main()
