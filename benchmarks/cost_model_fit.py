"""Cost-model calibration quality (paper §4.1: R^2 = 0.996 on 1,400 NVDEC
measurements; we re-fit on our codec as the paper prescribes)."""
from __future__ import annotations

from benchmarks.common import emit, shared_cost_model


def run():
    m = shared_cost_model()
    emit("cost_model/beta_s_per_pixel", m.beta * 1e6, f"{m.beta:.3e}")
    emit("cost_model/gamma_s_per_tile", m.gamma * 1e6, f"{m.gamma:.3e}")
    emit("cost_model/r_squared", 0.0, f"{m.r_squared:.4f}")
    emit("cost_model/encode_s_per_pixel", m.encode_per_pixel * 1e6,
         f"{m.encode_per_pixel:.3e}")
    return m


def main():
    run()


if __name__ == "__main__":
    main()
