"""Serving-layer benchmark: concurrent-scan throughput and tile-cache hit
rate, emitting ``BENCH_serving.json``.

Three regimes over the same overlapping scan workload (several clients
issuing car/person scans over sliding windows):

- ``serial_cold``  — N serial ``execute()`` calls, cache disabled: the
                     pre-serving-layer baseline (every tile decoded per
                     query).
- ``batched``      — the same scans through ``execute_many()`` on a fresh
                     store: overlapping SOTScans merge, each shared
                     ``(sot, tile)`` decodes at most once.
- ``served_warm``  — the same scans again through a ``serve()`` session on
                     the now-warm store: steady-state serving, cache hits
                     absorb (nearly) all decode work.

    PYTHONPATH=src python benchmarks/fig_serving.py              # full
    REPRO_QUICK=1 PYTHONPATH=src python benchmarks/fig_serving.py  # smoke

Also prints the usual ``name,us_per_call,derived`` CSV rows so it can ride
in ``benchmarks.run``.
"""
from __future__ import annotations

import json
import os
import pathlib
import time

from benchmarks.common import ENC, corpus_video, emit, shared_cost_model
from repro.core import CacheConfig
from repro.core import NoTilingPolicy, VideoStore

QUICK = bool(int(os.environ.get("REPRO_QUICK", "0")))
N_FRAMES = 128 if QUICK else 256
N_CLIENTS = 4 if QUICK else 8
SCANS_PER_CLIENT = 3 if QUICK else 6
WINDOW = 32
OUT = os.environ.get("REPRO_BENCH_OUT", "BENCH_serving.json")


def build_store(frames, dets, *, cache: bool) -> VideoStore:
    store = VideoStore(
        cache=CacheConfig(budget_bytes=None if cache else 0))
    store.add_video("cam0", encoder=ENC, policy=NoTilingPolicy(),
                    cost_model=shared_cost_model())
    store.ingest("cam0", frames)
    store.add_detections("cam0", {f: d for f, d in enumerate(dets)})
    return store


def workload(store) -> list:
    """Overlapping windows from several logical clients (deterministic)."""
    queries = []
    for c in range(N_CLIENTS):
        label = "car" if c % 2 == 0 else "person"
        for i in range(SCANS_PER_CLIENT):
            lo = ((c + 2 * i) * ENC.gop) % (N_FRAMES - WINDOW)
            queries.append(store.scan("cam0").labels(label)
                           .frames(lo, lo + WINDOW))
    return queries


def decoded(store) -> int:
    return store.video("cam0").store.tiles_decoded_total


def main() -> None:
    frames, dets, _ = corpus_video("sparse", 0, N_FRAMES)
    n_queries = N_CLIENTS * SCANS_PER_CLIENT
    report: dict = {"n_queries": n_queries, "n_frames": N_FRAMES}

    # -- serial, cache disabled (baseline) ---------------------------------
    store = build_store(frames, dets, cache=False)
    base = decoded(store)
    t0 = time.perf_counter()
    serial_res = [q.execute() for q in workload(store)]
    serial_s = time.perf_counter() - t0
    report["serial_cold"] = {
        "seconds": serial_s,
        "tiles_decoded": decoded(store) - base,
        "regions": sum(len(r.regions) for r in serial_res)}
    store.close()

    # -- batched through execute_many (cold cache) -------------------------
    store = build_store(frames, dets, cache=True)
    base = decoded(store)
    t0 = time.perf_counter()
    batch_res = store.execute_many(workload(store))
    batched_s = time.perf_counter() - t0
    hits = sum(r.stats.cache_hits for r in batch_res)
    misses = sum(r.stats.cache_misses for r in batch_res)
    report["batched"] = {
        "seconds": batched_s,
        "tiles_decoded": decoded(store) - base,
        "cache_hits": hits, "cache_misses": misses,
        "cache_hit_rate": hits / max(1, hits + misses)}

    # -- steady state: same workload again through a serving session -------
    base = decoded(store)
    t0 = time.perf_counter()
    with store.serve() as session:
        futs = [session.submit(q) for q in workload(store)]
        warm_res = [f.result() for f in futs]
    warm_s = time.perf_counter() - t0
    hits = sum(r.stats.cache_hits for r in warm_res)
    misses = sum(r.stats.cache_misses for r in warm_res)
    report["served_warm"] = {
        "seconds": warm_s,
        "tiles_decoded": decoded(store) - base,
        "cache_hits": hits, "cache_misses": misses,
        "cache_hit_rate": hits / max(1, hits + misses)}

    store.close()
    report["speedup_batched"] = serial_s / max(batched_s, 1e-9)
    report["speedup_warm"] = serial_s / max(warm_s, 1e-9)
    report["qps_serial"] = n_queries / max(serial_s, 1e-9)
    report["qps_warm"] = n_queries / max(warm_s, 1e-9)

    pathlib.Path(OUT).write_text(json.dumps(report, indent=1))
    emit("serving_serial_cold", 1e6 * serial_s / n_queries,
         f"tiles={report['serial_cold']['tiles_decoded']}")
    emit("serving_batched", 1e6 * batched_s / n_queries,
         f"tiles={report['batched']['tiles_decoded']};"
         f"hit_rate={report['batched']['cache_hit_rate']:.2f}")
    emit("serving_warm", 1e6 * warm_s / n_queries,
         f"tiles={report['served_warm']['tiles_decoded']};"
         f"hit_rate={report['served_warm']['cache_hit_rate']:.2f}")
    print(f"# wrote {OUT}: batched speedup "
          f"{report['speedup_batched']:.2f}x, warm speedup "
          f"{report['speedup_warm']:.2f}x")


if __name__ == "__main__":
    main()
