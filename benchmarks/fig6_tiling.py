"""Fig. 6: best-uniform vs best-non-uniform improvement in query time (a) and
stitched PSNR vs the untiled encoding (b).

Paper claims: best uniform ~37% mean improvement, best non-uniform ~51%
(and up to 94%); PSNR ~36 dB (uniform, many tiles) vs ~40 dB (non-uniform);
re-encode-untiled median ~46 dB.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (ENC, boxes_for, default_corpus, emit,
                               encode_video, encode_video_per_gop,
                               improvement, per_gop_layouts,
                               query_decode_seconds,
                               query_decode_seconds_per_gop, stitched_psnr)
from benchmarks.common import psnr_per_gop
from repro.core.layout import (fine_grained_layout, single_tile_layout,
                               uniform_layout)

UNIFORM_GRID = [(2, 2), (2, 3), (3, 3), (3, 5), (4, 4), (4, 6), (5, 5)]


def run(n_frames: int = 128, quiet: bool = False):
    rows = []
    for name, frames, dets in default_corpus(n_frames):
        H, W = frames.shape[1:]
        omega = single_tile_layout(H, W)
        enc_omega = encode_video(frames, omega)
        labels = sorted({l for d in dets for l, _ in d})
        for label in labels:
            bbf = boxes_for(dets, label, (0, n_frames))
            if len(bbf) < n_frames // 2:
                continue
            base_s, base_p, _ = query_decode_seconds(enc_omega, omega, bbf)

            best_u = None
            for r, c in UNIFORM_GRID:
                lay = uniform_layout(H, W, r, c)
                encs = encode_video(frames, lay)
                s, p, t = query_decode_seconds(encs, lay, bbf)
                if best_u is None or s < best_u[0]:
                    best_u = (s, lay, encs)
            # per-GOP non-uniform layouts (the real TASM setting: one SOT
            # per GOP, layout tracks the objects through time)
            layouts_n = per_gop_layouts(dets, lambda l: l == label, H, W,
                                        n_frames)
            encs_n = encode_video_per_gop(frames, layouts_n)
            s_n, p_n, t_n = query_decode_seconds_per_gop(encs_n, layouts_n, bbf)

            imp_u = improvement(base_s, best_u[0])
            imp_n = improvement(base_s, s_n)
            psnr_u = stitched_psnr(frames, best_u[2], best_u[1])
            psnr_n = psnr_per_gop(frames, encs_n, layouts_n)
            rows.append((name, label, imp_u, imp_n, psnr_u, psnr_n))
            if not quiet:
                n_tiles = int(np.median([l.n_tiles for l in layouts_n.values()]))
                emit(f"fig6/{name}/{label}/uniform_best", best_u[0] * 1e6,
                     f"improvement={imp_u:.1f}%;psnr={psnr_u:.1f}dB;layout={best_u[1].describe()}")
                emit(f"fig6/{name}/{label}/nonuniform", s_n * 1e6,
                     f"improvement={imp_n:.1f}%;psnr={psnr_n:.1f}dB;median_tiles={n_tiles}")
    imp_u = float(np.median([r[2] for r in rows]))
    imp_n = float(np.median([r[3] for r in rows]))
    emit("fig6/median_uniform_improvement", 0.0, f"{imp_u:.1f}%")
    emit("fig6/median_nonuniform_improvement", 0.0, f"{imp_n:.1f}%")
    emit("fig6/max_nonuniform_improvement", 0.0,
         f"{max(r[3] for r in rows):.1f}%")
    emit("fig6/mean_psnr_uniform", 0.0, f"{np.mean([r[4] for r in rows]):.1f}dB")
    emit("fig6/mean_psnr_nonuniform", 0.0, f"{np.mean([r[5] for r in rows]):.1f}dB")
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
