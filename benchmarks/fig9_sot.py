"""Fig. 9: SOT duration vs query time and storage size.

Paper claims: shorter SOTs decode faster (53% -> 36% going 1s -> 5s) but
store larger (1s SOT ~5% smaller than original vs 15% smaller for 5s; the
tiled-1s video is slightly SMALLER than the original due to recompression).
The tiled video uses GOP length == SOT duration.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (ENC, EncoderConfig, boxes_for, corpus_video,
                               emit, encode_video, improvement,
                               query_decode_seconds, storage_of)
from repro.codec.encode import encode_tile
from repro.core.layout import partition, single_tile_layout

SOT_GOPS = (1, 2, 4)  # SOT duration in multiples of the base 16-frame GOP


def run(n_frames: int = 128):
    out = {}
    for name_kind, seed in (("sparse", 0), ("sparse", 1), ("dense", 0)):
        frames, dets, _ = corpus_video(name_kind, seed, n_frames)
        H, W = frames.shape[1:]
        omega = single_tile_layout(H, W)
        enc_o = encode_video(frames, omega)  # untiled, 1s GOPs (the baseline)
        base_bytes = sum(e["size_bytes"] for e in enc_o)
        label = "car"
        bbf = boxes_for(dets, label, (0, n_frames))
        base_s, _, _ = query_decode_seconds(enc_o, omega, bbf)
        for sg in SOT_GOPS:
            sot_len = sg * ENC.gop
            enc_cfg = EncoderConfig(gop=sot_len, qp=ENC.qp)
            layouts, encs = {}, {}
            for s_i in range(n_frames // sot_len):
                lo, hi = s_i * sot_len, (s_i + 1) * sot_len
                boxes = [b for f in range(lo, hi) for l, b in dets[f]
                         if l == label]
                lay = partition(H, W, boxes, granularity="fine")
                layouts[s_i] = lay
                seg = frames[lo:hi]
                encs[s_i] = [encode_tile(
                    np.ascontiguousarray(seg[:, y1:y2, x1:x2]), enc_cfg)
                    for (y1, x1, y2, x2) in lay.tile_rects()]
            # decode time for the query under this SOT length
            import time

            by_sot: dict[int, set] = {}
            last_f: dict[int, int] = {}
            for f, boxes in bbf.items():
                s_i = f // sot_len
                need = by_sot.setdefault(s_i, set())
                last_f[s_i] = max(last_f.get(s_i, 0), f - s_i * sot_len + 1)
                for box in boxes:
                    need.update(layouts[s_i].tiles_intersecting(box))
            t0 = time.perf_counter()
            for s_i, tiles in by_sot.items():
                for t in tiles:
                    from repro.codec.encode import decode_tile

                    # decode only up to the last requested frame of the GOP
                    decode_tile(encs[s_i][t], gop_indices=[0],
                                frames_within=last_f[s_i])
            secs = time.perf_counter() - t0
            size = sum(e["size_bytes"] for tiles in encs.values()
                       for e in tiles)
            key = (f"{name_kind}{seed}", sg)
            out[key] = (improvement(base_s, secs),
                        100.0 * (size - base_bytes) / base_bytes)
    for sg in SOT_GOPS:
        imps = [v[0] for k, v in out.items() if k[1] == sg]
        sizes = [v[1] for k, v in out.items() if k[1] == sg]
        emit(f"fig9/sot_{sg}gop", 0.0,
             f"median_improvement={np.median(imps):.1f}%;"
             f"storage_vs_untiled={np.median(sizes):+.1f}%")
    return out


def main():
    run()


if __name__ == "__main__":
    main()
