"""Tuning-mode benchmark: per-query latency under ``tuning="inline"`` vs
``tuning="background"`` on the incremental (shifting) workload, emitting
``BENCH_tuning.json``.

The point of the background physical tuner: policy-driven re-tiling no
longer runs inside the scan that triggered it, so the *unlucky queries*
that used to pay the full re-encode stop paying it — per-query p95 drops —
while the tuner converges to the **same** physical design.  Three sections:

- ``inline``      — the pre-tuner behaviour: each policy-triggered re-tile
                    re-encodes synchronously inside the scan (its seconds
                    land in that query's wall time and ``retile_s``).
- ``background``  — the same workload; scans only emit observations, the
                    tuner re-tiles off the critical path.  A
                    ``drain_tuner()`` barrier after each query (outside the
                    timer) keeps the observation cadence identical to
                    inline, so final layouts / storage bytes / scan results
                    must match inline **exactly** — verified, not assumed.
- ``resume``      — persistence (manifest v3): the background store is
                    reopened from disk and must resume RegretPolicy tuning
                    from its persisted runtime state rather than cold.

    PYTHONPATH=src python benchmarks/fig_tuning.py              # full
    REPRO_QUICK=1 PYTHONPATH=src python benchmarks/fig_tuning.py  # smoke

Also prints ``name,us_per_call,derived`` CSV rows for ``benchmarks.run``.
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile
import time

import numpy as np

from benchmarks.common import ENC, corpus_video, emit, shared_cost_model
from repro.core import (CacheConfig, RegretPolicy, TuningConfig,
                        VideoStore)

QUICK = bool(int(os.environ.get("REPRO_QUICK", "0")))
N_FRAMES = 128 if QUICK else 256
N_QUERIES = 24 if QUICK else 60
WINDOW = 32
OUT = os.environ.get("REPRO_BENCH_OUT", "BENCH_tuning.json")


def workload():
    """The incremental workload (paper §5.3 W4): queries shift
    car -> person -> car over sliding windows; deterministic."""
    rng = np.random.default_rng(0)
    starts = rng.integers(0, N_FRAMES - WINDOW, N_QUERIES)
    labels = (["car"] * (N_QUERIES // 3) + ["person"] * (N_QUERIES // 3)
              + ["car"] * (N_QUERIES - 2 * (N_QUERIES // 3)))
    return list(zip(labels, [(int(s), int(s) + WINDOW) for s in starts]))


def build(model, frames, dets, *, mode, root=None):
    # cache off: the measured quantity is per-layout decode + tuning cost
    store = VideoStore(store_root=root, cache=CacheConfig(budget_bytes=0),
                       tuning=TuningConfig(mode=mode))
    store.add_video("v", encoder=ENC, policy=RegretPolicy(), cost_model=model)
    store.ingest("v", frames)
    store.add_detections("v", {f: d for f, d in enumerate(dets)})
    return store


def run_mode(store, queries, *, drain_each: bool):
    """Per-query wall latency of the scan itself.  For the background
    store a drain barrier runs after each query OUTSIDE the timer: the
    tuner still does all the re-encode work, queries just don't wait."""
    lat = []
    for label, t_range in queries:
        t0 = time.perf_counter()
        store.scan("v").labels(label).frames(*t_range).execute()
        lat.append(time.perf_counter() - t0)
        if drain_each:
            store.drain_tuner(timeout=300)
    return np.asarray(lat)


def layouts_of(store):
    return [(tuple(r.layout.heights), tuple(r.layout.widths), r.epoch)
            for r in store.video("v").store.sots]


def pcts(lat):
    return {"p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p95_ms": float(np.percentile(lat, 95) * 1e3),
            "mean_ms": float(lat.mean() * 1e3),
            "total_s": float(lat.sum())}


def main() -> None:
    frames, dets, _ = corpus_video("sparse", 1, N_FRAMES)
    model = shared_cost_model()
    queries = workload()
    report: dict = {"n_queries": N_QUERIES, "n_frames": N_FRAMES}

    # -- inline: queries pay the re-encode -------------------------------
    # both stores disk-backed so re-encode costs are apples-to-apples (the
    # background one doubles as the resume-section fixture)
    inline = build(model, frames, dets, mode="inline",
                   root=tempfile.mkdtemp(prefix="tasm_tuning_in_"))
    lat_in = run_mode(inline, queries, drain_each=False)
    retile_in = sum(s.retile_s for s in inline.history)
    report["inline"] = {**pcts(lat_in), "retile_s": retile_in,
                       "queries_charged": sum(
                           1 for s in inline.history if s.retile_s > 0)}

    # -- background: tuner pays it off the critical path -----------------
    root = tempfile.mkdtemp(prefix="tasm_tuning_")
    bg = build(model, frames, dets, mode="background", root=root)
    lat_bg = run_mode(bg, queries, drain_each=True)
    ts = bg.tuner_stats()
    charged = sum(1 for s in bg.history if s.retile_s > 0)
    report["background"] = {
        **pcts(lat_bg), "queries_charged": charged,
        "tuner": {"observed": ts.observed, "proposals": ts.proposals,
                  "coalesced": ts.coalesced, "applied": ts.applied,
                  "skipped": ts.skipped, "retile_s": ts.retile_s,
                  "tuning_s": ts.tuning_s,
                  "est_savings_s": ts.est_savings_s,
                  "est_reencode_s": ts.est_reencode_s}}
    if charged:
        raise RuntimeError("background queries were charged retile time")

    # -- identity: same physical design, bit-identical results -----------
    if layouts_of(bg) != layouts_of(inline):
        raise RuntimeError("background converged to different layouts")
    if bg.storage_bytes() != inline.storage_bytes():
        raise RuntimeError("background storage bytes diverged")
    ri = inline.scan("v").labels("car").frames(0, N_FRAMES).execute()
    rb = bg.scan("v").labels("car").frames(0, N_FRAMES).execute()
    same = len(ri.regions) == len(rb.regions) and all(
        a[:2] == b[:2] and np.array_equal(a[2], b[2])
        for a, b in zip(ri.regions, rb.regions))
    if not same:
        raise RuntimeError("background scan results diverged from inline")
    report["identity"] = {"layouts_match": True, "storage_match": True,
                          "results_bit_identical": True,
                          "n_retiled_sots": sum(
                              1 for *_, e in layouts_of(bg) if e > 0)}
    inline.close()
    bg.drain_tuner(timeout=300)
    bg.close()

    # -- resume: reopened store tunes from persisted regret, not cold ----
    reopened = VideoStore(store_root=root,
                          cache=CacheConfig(budget_bytes=0))
    pol = reopened.video("v").policy
    state = pol.state_dict()
    if not state["seen"]:
        raise RuntimeError("reopened RegretPolicy came back cold")
    report["resume"] = {
        "seen": state["seen"],
        "regret_entries": len(state["regret"]),
        "state_roundtrips": state == bg.video("v").policy.state_dict()}
    reopened.close()

    report["p95_speedup"] = report["inline"]["p95_ms"] / \
        max(report["background"]["p95_ms"], 1e-9)
    pathlib.Path(OUT).write_text(json.dumps(report, indent=1))
    emit("tuning_inline", 1e6 * lat_in.sum() / N_QUERIES,
         f"p95_ms={report['inline']['p95_ms']:.1f};"
         f"retile_s={retile_in:.3f}")
    emit("tuning_background", 1e6 * lat_bg.sum() / N_QUERIES,
         f"p95_ms={report['background']['p95_ms']:.1f};"
         f"applied={ts.applied};tuner_retile_s={ts.retile_s:.3f}")
    print(f"# wrote {OUT}: p95 {report['inline']['p95_ms']:.1f}ms -> "
          f"{report['background']['p95_ms']:.1f}ms "
          f"({report['p95_speedup']:.2f}x), layouts/bytes/results identical, "
          f"resume={report['resume']['state_roundtrips']}")


if __name__ == "__main__":
    main()
