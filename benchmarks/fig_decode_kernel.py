"""Batched fused decode benchmark, emitting ``BENCH_decode_kernel.json``.

The "batched" decode backend flattens a whole merged group fetch — every
(tile, GOP, block-mask) selection — into one fused dequant+IDCT+cumsum
dispatch per size bucket, instead of the numpy oracle's per-tile Python
loop.  This benchmark measures that claim where it matters: a fine-tiled
>=32-tile merged batch (the union-of-tiles shape TASM's scheduler
actually produces), full-tile and ROI-masked, plus the end-to-end scan
path under both backends.

Hard gates (the CI smoke fails if they regress):
- bit-identity of the batched backend against the numpy oracle, on both
  the cold (first post-jit-warm) and warm (repeat) decode;
- ``ScanStats`` pixel/tile accounting and the ``TileStore`` decode
  counters identical under both backends.
Latency gate (soft under ``--quick``: single-sample timings + CI noise):
- >= 1.5x cold decode throughput on the >=32-tile merged batch.

    PYTHONPATH=src python benchmarks/fig_decode_kernel.py              # full
    REPRO_QUICK=1 PYTHONPATH=src python benchmarks/fig_decode_kernel.py

Also prints ``name,us_per_call,derived`` CSV rows for ``benchmarks.run``.
"""
from __future__ import annotations

import json
import os
import pathlib
import time

import numpy as np

from benchmarks.common import ENC, corpus_video, emit, gate, quick_mode
from repro.core import (CacheConfig, DecodeConfig, NoTilingPolicy,
                        VideoStore, uniform_layout)
from repro.core.storage import TileStore

QUICK = quick_mode()
N_FRAMES = 32 if QUICK else 64
H, W = 192, 320
GRID = (6, 8)          # 48 tiles of 32x40 px -> 20 blocks/tile: the fine-
                       # tiled regime where per-tile loop overhead dominates
ROI_BLOCKS = 6         # blocks kept per tile in the ROI scenario
REPEATS = 2 if QUICK else 5
OUT = os.environ.get("REPRO_BENCH_OUT", "BENCH_decode_kernel.json")

MIN_SPEEDUP = 1.5


def build_store(frames, backend: str) -> TileStore:
    ts = TileStore("bench", ENC, sot_len=N_FRAMES, decode_backend=backend)
    ts.ingest(frames)
    ts.retile(0, uniform_layout(H, W, *GRID))
    return ts


def roi_masks(n_tiles: int) -> dict:
    """A fixed pseudo-random ROI: ROI_BLOCKS of the 20 blocks per tile."""
    rng = np.random.default_rng(42)
    nb = (H // GRID[0] // 8) * (W // GRID[1] // 8)
    return {t: tuple(sorted(rng.choice(nb, ROI_BLOCKS, replace=False)
                            .tolist()))
            for t in range(n_tiles)}


def time_decodes(ts: TileStore, tiles, blocks):
    """(cold output, warm output, median seconds/batch, pixels/batch).

    The first decode after a throwaway jit/allocator warm-up is the
    "cold" sample — cold CACHE, warm COMPILER: jit compilation is a
    once-per-bucket cost the serving layer never pays per batch, so it is
    excluded from the timed region for both backends alike."""
    ts.decode_tiles(0, tiles, blocks=blocks)    # warm jit traces/allocators
    base = ts.pixels_decoded_total
    t0 = time.perf_counter()
    cold = ts.decode_tiles(0, tiles, blocks=blocks)
    times = [time.perf_counter() - t0]
    pixels = ts.pixels_decoded_total - base
    warm = cold
    for _ in range(REPEATS - 1):
        t0 = time.perf_counter()
        warm = ts.decode_tiles(0, tiles, blocks=blocks)
        times.append(time.perf_counter() - t0)
    return cold, warm, float(np.median(times)), pixels


def assert_tiles_equal(a: dict, b: dict, where: str) -> None:
    assert sorted(a) == sorted(b), where
    for t in a:
        if not np.array_equal(a[t], b[t]):
            raise AssertionError(
                f"{where}: batched decode not bit-identical to the numpy "
                f"oracle at tile {t}")


def scan_parity(frames, dets):
    """Run the same scan workload under both backends; return the paired
    (ScanStats pixel/tile, TileStore counter) accounting."""
    out = {}
    for backend in ("numpy", "batched"):
        s = VideoStore(decode=DecodeConfig(backend=backend),
                       cache=CacheConfig(budget_bytes=0))
        s.add_video("cam0", encoder=ENC, policy=NoTilingPolicy())
        s.ingest("cam0", frames)
        s.add_detections("cam0", {f: d for f, d in enumerate(dets)})
        s.retile("cam0", 0, uniform_layout(H, W, 3, 4))
        res = [s.scan("cam0").labels("car").frames(0, N_FRAMES).execute(),
               s.scan("cam0").labels("person").frames(5, 27).execute()]
        st = s.video("cam0").store
        out[backend] = {
            "regions": [r.regions for r in res],
            "scan_pixels": [r.stats.pixels_decoded for r in res],
            "scan_tiles": [r.stats.tiles_fetched for r in res],
            "tiles_decoded_total": st.tiles_decoded_total,
            "pixels_decoded_total": st.pixels_decoded_total,
        }
        s.close()
    return out


def main() -> None:
    frames, dets, _ = corpus_video("sparse", 0, N_FRAMES, height=H, width=W)
    n_tiles = GRID[0] * GRID[1]
    tiles = list(range(n_tiles))
    report: dict = {"n_frames": N_FRAMES, "grid": list(GRID),
                    "n_tiles": n_tiles, "repeats": REPEATS,
                    "scenarios": {}}

    stores = {b: build_store(frames, b) for b in ("numpy", "batched")}
    for name, blocks in (("full", None), ("roi", roi_masks(n_tiles))):
        runs = {b: time_decodes(stores[b], tiles, blocks)
                for b in ("numpy", "batched")}
        cold_np, warm_np, t_np, px_np = runs["numpy"]
        cold_b, warm_b, t_b, px_b = runs["batched"]
        assert_tiles_equal(cold_np, cold_b, f"{name}/cold")
        assert_tiles_equal(warm_np, warm_b, f"{name}/warm")
        gate(px_np == px_b,
             f"{name}: pixel counters diverge ({px_np} vs {px_b})")
        speedup = t_np / max(t_b, 1e-12)
        report["scenarios"][name] = {
            "numpy_s_per_batch": t_np, "batched_s_per_batch": t_b,
            "pixels_per_batch": px_np, "speedup": speedup,
            "bit_identical": True,
        }
        emit(f"decode_kernel/{name}/numpy", 1e6 * t_np,
             f"{n_tiles}-tile batch; px={px_np / 1e6:.2f}M")
        emit(f"decode_kernel/{name}/batched", 1e6 * t_b,
             f"speedup={speedup:.2f}x")

    parity = scan_parity(frames, dets)
    a, b = parity["numpy"], parity["batched"]
    for ra, rb in zip(a["regions"], b["regions"]):
        assert len(ra) == len(rb), "scan region counts diverge"
        for x, y in zip(ra, rb):
            gate(x[:-1] == y[:-1] and np.array_equal(x[-1], y[-1]),
                 "scan regions not bit-identical across backends")
    gate(a["scan_pixels"] == b["scan_pixels"] and
         a["scan_tiles"] == b["scan_tiles"],
         "ScanStats accounting diverges across backends")
    gate(a["tiles_decoded_total"] == b["tiles_decoded_total"] and
         a["pixels_decoded_total"] == b["pixels_decoded_total"],
         "TileStore decode counters diverge across backends")
    report["scan_parity"] = {
        "pixels_decoded_total": a["pixels_decoded_total"],
        "tiles_decoded_total": a["tiles_decoded_total"],
        "identical": True,
    }
    emit("decode_kernel/scan_parity", 0.0,
         f"counters identical; px={a['pixels_decoded_total'] / 1e6:.2f}M")

    full = report["scenarios"]["full"]
    pathlib.Path(OUT).write_text(json.dumps(report, indent=1))
    print(f"# wrote {OUT}: {n_tiles}-tile batch "
          f"{full['speedup']:.2f}x (full), "
          f"{report['scenarios']['roi']['speedup']:.2f}x (roi)")

    # bit-identity/counters gated hard above in every mode; the throughput
    # gate compares few-sample timings, so quick mode demotes it
    gate(full["speedup"] >= MIN_SPEEDUP,
         f"batched decode {full['speedup']:.2f}x < {MIN_SPEEDUP}x on a "
         f"{n_tiles}-tile merged batch", hard=not QUICK)


if __name__ == "__main__":
    main()
