"""Shared benchmark plumbing: video corpus, calibrated cost model, timing of
queries under explicit layouts, and CSV emission (name,us_per_call,derived).
"""
from __future__ import annotations

import functools
import os
import time

import numpy as np

from repro.codec.encode import EncoderConfig, decode_tile, encode_tile
from repro.codec.psnr import psnr
from repro.core.cost import CostModel
from repro.core.layout import TileLayout, single_tile_layout
from repro.data.video_gen import (VideoSpec, dense_spec, generate,
                                  multiclass_spec, sparse_spec)

ENC = EncoderConfig(gop=16, qp=8)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def quick_mode() -> bool:
    """True under ``REPRO_QUICK=1`` (CI smoke / ``run.py --quick``)."""
    return bool(int(os.environ.get("REPRO_QUICK", "0")))


def gate(ok: bool, message: str, *, hard: bool = True) -> bool:
    """Benchmark acceptance gate.  A failing hard gate raises (the CI smoke
    goes red); a failing soft gate prints a warning row and keeps going.

    Correctness gates (pixel counts, bit-identity) should stay hard in
    every mode.  LATENCY gates should pass ``hard=not quick_mode()``: quick
    mode runs single-sample timings, so CI-runner noise can fail a correct
    build — there the measurement is reported, warned on, but not fatal.
    Full runs keep every gate hard.  Returns ``ok`` so callers can record
    the verdict in their report JSON."""
    if ok:
        return True
    if hard:
        raise AssertionError(message)
    print(f"# WARNING soft-gate failed: {message}", flush=True)
    return False


def w6_spec(seed=0, n_frames=256, height=192, width=320) -> VideoSpec:
    """Fig.-11 W6 regime: tiling around the (small, sparse) queried object
    helps, but tiling around ALL objects hurts (the rest are large/dense)."""
    from repro.data.video_gen import ObjectSpec

    return VideoSpec(
        height=height, width=width, n_frames=n_frames, seed=seed,
        objects=[
            ObjectSpec("person", 2, (22, 10), 1.2, 240.0),
            ObjectSpec("car", 5, (48, 80), 2.0, 210.0),
            ObjectSpec("boat", 3, (56, 90), 1.0, 180.0),
        ])


@functools.lru_cache(maxsize=32)
def corpus_video(kind: str, seed: int, n_frames: int = 256,
                 height: int = 192, width: int = 320):
    """kind: sparse | dense | multiclass | w6.  Cached per process."""
    fn = {"sparse": sparse_spec, "dense": dense_spec,
          "multiclass": multiclass_spec, "w6": w6_spec}[kind]
    spec = fn(seed=seed, n_frames=n_frames, height=height, width=width)
    frames, dets = generate(spec)
    return frames, dets, spec


def default_corpus(n_frames: int = 256):
    """(name, frames, detections) across sparse/dense regimes (Table 1)."""
    out = []
    for kind in ("sparse", "dense"):
        for seed in (0, 1):
            frames, dets, _ = corpus_video(kind, seed, n_frames)
            out.append((f"{kind}{seed}", frames, dets))
    return out


@functools.lru_cache(maxsize=1)
def shared_cost_model() -> CostModel:
    from repro.core.calibrate import calibrated_cost_model

    return calibrated_cost_model(ENC, seeds=(0,), repeats=1)


# --------------------------------------------------------------------------
# Direct layout measurement (microbenchmarks): encode a whole video under one
# layout, run an object query, time the decode.
# --------------------------------------------------------------------------
def encode_video(frames: np.ndarray, layout: TileLayout,
                 enc: EncoderConfig = ENC) -> list[dict]:
    return [encode_tile(np.ascontiguousarray(frames[:, y1:y2, x1:x2]), enc)
            for (y1, x1, y2, x2) in layout.tile_rects()]


def query_decode_seconds(encs: list[dict], layout: TileLayout, boxes_by_frame,
                         enc: EncoderConfig = ENC, repeats: int = 1):
    """Decode the tiles covering the query boxes GOP-by-GOP (as TASM would).

    Returns (seconds, pixels, tiles_opened)."""
    by_gop: dict[int, set[int]] = {}
    for f, boxes in boxes_by_frame.items():
        g = f // enc.gop
        need = by_gop.setdefault(g, set())
        for box in boxes:
            need.update(layout.tiles_intersecting(box))
    # warm any lazily-allocated buffers
    for g, tiles in list(by_gop.items())[:1]:
        for t in list(tiles)[:1]:
            decode_tile(encs[t], gop_indices=[g])
    t0 = time.perf_counter()
    for _ in range(repeats):
        for g, tiles in by_gop.items():
            for t in tiles:
                decode_tile(encs[t], gop_indices=[g])
    secs = (time.perf_counter() - t0) / repeats
    pixels = sum(encs[t]["h"] * encs[t]["w"] * enc.gop
                 for g, tiles in by_gop.items() for t in tiles)
    n_tiles = sum(len(tiles) for tiles in by_gop.values())
    return secs, pixels, n_tiles


def boxes_for(dets, label: str, frame_range=None):
    lo, hi = frame_range or (0, len(dets))
    out = {}
    for f in range(lo, min(hi, len(dets))):
        boxes = [b for l, b in dets[f] if l == label]
        if boxes:
            out[f] = boxes
    return out


def stitched_psnr(frames: np.ndarray, encs: list[dict],
                  layout: TileLayout) -> float:
    """Quality of the tiled encoding vs the original (homomorphic stitch)."""
    T, H, W = frames.shape
    recon = np.zeros_like(frames)
    for i, (y1, x1, y2, x2) in enumerate(layout.tile_rects()):
        recon[:, y1:y2, x1:x2] = decode_tile(encs[i])[:T]
    return psnr(frames, recon)


def improvement(untiled_s: float, tiled_s: float) -> float:
    """Paper's 'improvement in query time' percentage."""
    return 100.0 * (untiled_s - tiled_s) / untiled_s


# --------------------------------------------------------------------------
# Per-SOT (per-GOP) layout encodes — the real TASM setting for non-uniform
# layouts: each GOP gets its own layout tracking object positions.
# --------------------------------------------------------------------------
def encode_video_per_gop(frames: np.ndarray, layouts: dict[int, TileLayout],
                         enc: EncoderConfig = ENC):
    """layouts: gop index -> layout.  Returns {gop: [tile encodings]}."""
    T = frames.shape[0]
    out = {}
    for g in range(T // enc.gop):
        seg = frames[g * enc.gop:(g + 1) * enc.gop]
        lay = layouts[g]
        out[g] = [encode_tile(np.ascontiguousarray(seg[:, y1:y2, x1:x2]), enc)
                  for (y1, x1, y2, x2) in lay.tile_rects()]
    return out


def query_decode_seconds_per_gop(encs_by_gop, layouts: dict[int, TileLayout],
                                 boxes_by_frame, enc: EncoderConfig = ENC,
                                 repeats: int = 1):
    by_gop: dict[int, set[int]] = {}
    for f, boxes in boxes_by_frame.items():
        g = f // enc.gop
        need = by_gop.setdefault(g, set())
        for box in boxes:
            need.update(layouts[g].tiles_intersecting(box))
    t0 = time.perf_counter()
    for _ in range(repeats):
        for g, tiles in by_gop.items():
            for t in tiles:
                decode_tile(encs_by_gop[g][t], gop_indices=[0])
    secs = (time.perf_counter() - t0) / repeats
    pixels = sum(encs_by_gop[g][t]["h"] * encs_by_gop[g][t]["w"] * enc.gop
                 for g, tiles in by_gop.items() for t in tiles)
    n_tiles = sum(len(t) for t in by_gop.values())
    return secs, pixels, n_tiles


def per_gop_layouts(dets, label_filter, H: int, W: int, n_frames: int,
                    enc: EncoderConfig = ENC, granularity: str = "fine"):
    """gop -> PARTITION(gop frames, labels) fine/coarse layout."""
    from repro.core.layout import partition

    layouts = {}
    for g in range(n_frames // enc.gop):
        boxes = [b for f in range(g * enc.gop, (g + 1) * enc.gop)
                 for l, b in dets[f] if label_filter(l)]
        layouts[g] = partition(H, W, boxes, granularity=granularity)
    return layouts


def storage_of(encs_by_gop) -> float:
    return sum(e["size_bytes"] for encs in encs_by_gop.values() for e in encs)


def psnr_per_gop(frames: np.ndarray, encs_by_gop, layouts,
                 enc: EncoderConfig = ENC) -> float:
    recon = np.zeros_like(frames)
    for g, encs in encs_by_gop.items():
        lay = layouts[g]
        for i, (y1, x1, y2, x2) in enumerate(lay.tile_rects()):
            recon[g * enc.gop:(g + 1) * enc.gop, y1:y2, x1:x2] = \
                decode_tile(encs[i], gop_indices=[0])
    return psnr(frames, recon)
