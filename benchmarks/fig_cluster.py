"""Distributed VideoStore benchmark: a 3-node cluster behind
``ClusterRouter`` vs one in-process store, emitting ``BENCH_cluster.json``.

The claim under test is the router tier's contract: consistent-hash
placement spreads a video corpus evenly across nodes, fan-out batch
execution returns results BIT-IDENTICAL to a single store, and
primary-first routing keeps each video's repeat scans on one warm tile
cache.  One corpus of ``N_VIDEOS`` videos is ingested twice — into a
single reference ``VideoStore`` and through the router into 3 socket
nodes with K=2 replication — then the same ``execute_many`` batch runs
against both.

Hard gates (CI fails if the distributed tier diverges):
- the cluster batch is bit-identical to the single store's (region keys
  AND pixels, canonical digest), and so is a warm repeat;
- placement balance: with ``#videos >= 4 x #nodes``, the busiest node
  primaries at most 2x the least busy (bounded-load placement actually
  keeps the spread <= 1 video);
- warm-repeat locality: re-running the batch leaves EVERY node's
  ``tiles_decoded_total`` unchanged — replicated routing still sends
  each video to the same warm primary, so no node re-decodes anything.

Throughput (batch makespan single vs fanned-out) is reported; the gate
is soft in quick mode (single-sample wall clock on a shared CI runner)
and hard in full runs, where 3-node fan-out must not be catastrophically
slower than in-process execution despite shipping every pixel over a
socket.

    PYTHONPATH=src:. python benchmarks/fig_cluster.py              # full
    REPRO_QUICK=1 PYTHONPATH=src:. python benchmarks/fig_cluster.py  # smoke
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import tempfile
import time

import numpy as np

from benchmarks.common import ENC, corpus_video, emit, gate, quick_mode

QUICK = quick_mode()
N_NODES = 3
REPLICATION = 2
N_VIDEOS = 12                      # >= 4 x N_NODES arms the balance gate
N_FRAMES = 32 if QUICK else 64
H, W = 96, 160
OUT = os.environ.get("REPRO_BENCH_OUT", "BENCH_cluster.json")

VIDEOS = [f"cam{i:02d}" for i in range(N_VIDEOS)]


def corpus():
    return {v: corpus_video("sparse", i, N_FRAMES, height=H, width=W)[:2]
            for i, v in enumerate(VIDEOS)}


def seed(store, videos: dict) -> None:
    """Identical declarative setup on the reference store and (routed to
    every replica) through the cluster — encode is deterministic, so both
    worlds hold byte-identical tiles."""
    from repro.core import NoTilingPolicy

    for name, (frames, dets) in videos.items():
        store.add_video(name, encoder=ENC, policy=NoTilingPolicy())
        store.ingest(name, frames)
        store.add_detections(name, {f: d for f, d in enumerate(dets)})


def workload(store) -> list:
    """Two scans per video (full-range car + offset person window) plus
    one multi-video scan per adjacent pair — the pairs exercise the
    router's cross-node split/merge path whenever placement separates
    them."""
    qs = []
    for i, v in enumerate(VIDEOS):
        qs.append(store.scan(v).labels("car").frames(0, N_FRAMES))
        lo = (i * ENC.gop) % (N_FRAMES - ENC.gop)
        qs.append(store.scan(v).labels("person").frames(lo, lo + ENC.gop))
    for a, b in zip(VIDEOS[::2], VIDEOS[1::2]):
        qs.append(store.scan([a, b]).labels("car").frames(0, ENC.gop))
    return qs


def digest(results) -> str:
    h = hashlib.sha256()
    for r in results:
        for reg in r.regions:  # (f, box, px) or (video, f, box, px)
            *key, px = reg
            h.update(repr((tuple(key), px.shape, str(px.dtype))).encode())
            h.update(np.ascontiguousarray(px).tobytes())
    return h.hexdigest()


def main() -> None:
    from repro.core import ClusterRouter, VideoStore, VideoStoreServer

    videos = corpus()
    tmp = tempfile.mkdtemp(prefix="tasm_fig_cluster_")
    report: dict = {"n_nodes": N_NODES, "n_videos": N_VIDEOS,
                    "replication": REPLICATION, "n_frames": N_FRAMES}

    # -- single in-process store: the bit-identity + throughput baseline --
    ref = VideoStore()
    seed(ref, videos)
    plans = [q.plan() for q in workload(ref)]  # engine-independent logic
    n_queries = len(plans)
    t0 = time.perf_counter()
    ref_results = ref.execute_many(plans)
    single_s = time.perf_counter() - t0
    ref_digest = digest(ref_results)
    report["single"] = {"batch_s": single_s,
                        "qps": n_queries / max(single_s, 1e-9)}

    # -- the cluster: 3 socket nodes, K=2, routed ingest + batch ----------
    stores = [VideoStore() for _ in range(N_NODES)]
    servers = [VideoStoreServer(s, path=os.path.join(tmp, f"n{i}.sock"),
                                owns_store=False).start()
               for i, s in enumerate(stores)]
    router = ClusterRouter(
        {f"n{i}": os.path.join(tmp, f"n{i}.sock")
         for i in range(N_NODES)},
        replication=REPLICATION,
        placement_path=os.path.join(tmp, "placement.json"))
    try:
        t0 = time.perf_counter()
        seed(router, videos)
        report["cluster_ingest_s"] = time.perf_counter() - t0

        counts = {n: 0 for n in router.placement.nodes}
        for reps in router.placement.assignments.values():
            counts[reps[0]] += 1
        report["primaries_per_node"] = counts
        assert N_VIDEOS >= 4 * N_NODES  # the balance gate's precondition
        gate(max(counts.values()) <= 2 * max(min(counts.values()), 1),
             f"placement imbalance: primaries {counts}")

        t0 = time.perf_counter()
        cluster_results = router.execute_many(plans)
        cluster_s = time.perf_counter() - t0
        report["cluster"] = {"batch_s": cluster_s,
                             "qps": n_queries / max(cluster_s, 1e-9)}
        report["bit_identical"] = digest(cluster_results) == ref_digest
        gate(report["bit_identical"],
             "cluster execute_many diverges from the single store")

        # warm repeat: same batch again — primary-first routing must land
        # every scan on the node that already decoded it
        tiles_before = {n: (d or {}).get("tiles_decoded_total", 0)
                        for n, d in router.stats()["nodes"].items()}
        t0 = time.perf_counter()
        warm_results = router.execute_many(plans)
        warm_s = time.perf_counter() - t0
        tiles_after = {n: (d or {}).get("tiles_decoded_total", 0)
                       for n, d in router.stats()["nodes"].items()}
        deltas = {n: tiles_after[n] - tiles_before[n] for n in tiles_after}
        report["warm"] = {"batch_s": warm_s,
                          "qps": n_queries / max(warm_s, 1e-9),
                          "tiles_decoded_per_node": deltas}
        gate(all(d == 0 for d in deltas.values()),
             f"warm repeat re-decoded tiles per node: {deltas}")
        gate(digest(warm_results) == ref_digest,
             "warm cluster repeat diverges from the single store")

        report["speedup_cluster"] = single_s / max(cluster_s, 1e-9)
        # soft in quick mode (single-sample timing on a noisy runner);
        # full runs must hold: fan-out across 3 nodes, even paying socket
        # marshalling for every pixel, stays within 2x of in-process
        gate(report["speedup_cluster"] >= 0.5,
             f"cluster batch {cluster_s:.3f}s vs single {single_s:.3f}s "
             f"(speedup {report['speedup_cluster']:.2f}x < 0.5x)",
             hard=not QUICK)
    finally:
        router.close()
        for srv in servers:
            srv.stop()
        for s in stores:
            s.close()
        ref.close()

    pathlib.Path(OUT).write_text(json.dumps(report, indent=1))
    emit("cluster_single", 1e6 * single_s / n_queries,
         f"qps={report['single']['qps']:.1f}")
    emit("cluster_fanout", 1e6 * cluster_s / n_queries,
         f"qps={report['cluster']['qps']:.1f};"
         f"speedup={report['speedup_cluster']:.2f}x")
    emit("cluster_warm", 1e6 * warm_s / n_queries,
         f"qps={report['warm']['qps']:.1f};tiles=0")
    print(f"# wrote {OUT}: {N_VIDEOS} videos over {N_NODES} nodes (K="
          f"{REPLICATION}), primaries {report['primaries_per_node']}, "
          f"bit_identical={report['bit_identical']}, cluster speedup "
          f"{report['speedup_cluster']:.2f}x, warm per-node decodes 0")


if __name__ == "__main__":
    main()
