"""Fig. 8: effect of tile granularity (fine vs coarse) and of which objects
the layout targets (same / different / all / superset), split sparse/dense.

Paper claims: fine >= coarse everywhere; 'same' best (79%/51% sparse/dense
fine); 'different' can hurt when dense; 'all' works for sparse (68%) but not
dense (21% fine, -1% coarse); 'superset' ~= 'all'.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (ENC, boxes_for, corpus_video, emit,
                               encode_video, encode_video_per_gop,
                               improvement, per_gop_layouts,
                               query_decode_seconds,
                               query_decode_seconds_per_gop)
from repro.core.layout import single_tile_layout

CATEGORIES = ("same", "different", "all", "superset")


def run(n_frames: int = 128):
    results: dict[tuple, list] = {}
    for regime in ("sparse", "dense"):
        for seed in (0, 1):
            frames, dets, _ = corpus_video("multiclass" if regime == "sparse"
                                           else "dense", seed, n_frames)
            H, W = frames.shape[1:]
            omega = single_tile_layout(H, W)
            enc_o = encode_video(frames, omega)
            labels = sorted({l for d in dets for l, _ in d})
            primary = [l for l in labels
                       if sum(1 for d in dets for ll, _ in d if ll == l)
                       >= n_frames]
            for q_label in primary[:2]:
                bbf = boxes_for(dets, q_label, (0, n_frames))
                base_s, _, _ = query_decode_seconds(enc_o, omega, bbf)
                others = [l for l in primary if l != q_label]
                targets = {
                    "same": lambda l, q=q_label: l == q,
                    "different": (lambda l, o=others[0]: l == o) if others else None,
                    "all": lambda l: True,
                    "superset": (lambda l, q=q_label, o=others[:1]:
                                 l == q or l in o) if others else None,
                }
                for cat, pred in targets.items():
                    if pred is None:
                        continue
                    for gran in ("fine", "coarse"):
                        lays = per_gop_layouts(dets, pred, H, W, n_frames,
                                               granularity=gran)
                        encs = encode_video_per_gop(frames, lays)
                        s, _, _ = query_decode_seconds_per_gop(encs, lays, bbf)
                        results.setdefault((regime, cat, gran), []).append(
                            improvement(base_s, s))
    for key in sorted(results):
        vals = np.array(results[key])
        emit(f"fig8/{key[0]}/{key[1]}/{key[2]}", 0.0,
             f"median={np.median(vals):.1f}%;n={len(vals)}")
    return results


def main():
    run()


if __name__ == "__main__":
    main()
