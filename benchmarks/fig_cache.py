"""Workload-predictive tile cache benchmark, emitting ``BENCH_cache.json``.

Four regimes over the serving-layer cache (``core/tile_cache.py``):

- ``sliding``  — a client scanning sliding windows over a video, predictive
                 config on (prefetch + reuse eviction + block packing) vs a
                 cache-off control.  HARD gates: every window bit-identical
                 to the control, and once the predictor locks on, a whole
                 warm window decodes 0 tiles (misses == 0, pixels == 0).
- ``packed``   — an ROI-decode trace captured from a real sparse-video
                 workload, replayed into block-packed vs zero-padded caches
                 sharing the same tight byte budget.  HARD gate: the packed
                 cache holds >= 2x the entries, serving identical pixels.
- ``lru``      — a randomized put/get/invalidate trace replayed against a
                 literal re-implementation of the pre-predictive cache.
                 HARD gate: ``eviction="lru"`` reproduces its eviction
                 order and counters byte-for-byte.
- ``latency``  — wall time of a fully-warm predictive pass vs the cache-off
                 control.  SOFT gate in quick mode (single-sample timing),
                 hard in full runs: warm must beat cache-off.

    PYTHONPATH=src python benchmarks/fig_cache.py                # full
    REPRO_QUICK=1 PYTHONPATH=src python benchmarks/fig_cache.py  # smoke

Also prints ``name,us_per_call,derived`` CSV rows for ``benchmarks.run``.
"""
from __future__ import annotations

import json
import os
import pathlib
import time
from collections import OrderedDict

import numpy as np

from benchmarks.common import (ENC, corpus_video, emit, gate, quick_mode,
                               shared_cost_model)
from repro.core import CacheConfig, NoTilingPolicy, TileCache, VideoStore
from repro.core.tile_cache import _covers

QUICK = quick_mode()
N_FRAMES = 128 if QUICK else 256
WINDOW = 32
OUT = os.environ.get("REPRO_BENCH_OUT", "BENCH_cache.json")

PREDICTIVE = CacheConfig(prefetch=True, prefetch_depth=2,
                         eviction="reuse", block_packed=True)


def build_store(frames, dets, *, cache: CacheConfig) -> VideoStore:
    store = VideoStore(cache=cache)
    store.add_video("cam0", encoder=ENC, policy=NoTilingPolicy(),
                    cost_model=shared_cost_model(), sot_len=WINDOW)
    store.ingest("cam0", frames)
    store.add_detections("cam0", {f: d for f, d in enumerate(dets)})
    return store


def windows(store):
    return [store.scan("cam0").labels("car").frames(i * WINDOW,
                                                    (i + 1) * WINDOW)
            for i in range(N_FRAMES // WINDOW)]


def regions_equal(a, b) -> bool:
    if len(a) != len(b):
        return False
    return all(ra[:-1] == rb[:-1] and np.array_equal(ra[-1], rb[-1])
               for ra, rb in zip(a, b))


# ------------------------------------------------------------- sliding wave
def bench_sliding(report, pred, ctrl) -> None:
    t0 = time.perf_counter()
    waves = []
    identical = True
    for qp, qc in zip(windows(pred), windows(ctrl)):
        rp, rc = qp.execute(), qc.execute()
        identical &= regions_equal(rp.regions, rc.regions)
        pred.drain_prefetch(timeout=60)
        waves.append({"misses": rp.stats.cache_misses,
                      "pixels": rp.stats.pixels_decoded})
    elapsed = time.perf_counter() - t0
    cs = pred.tile_cache.stats()
    report["sliding"] = {
        "waves": waves,
        "prefetch_issued": cs.prefetch_issued,
        "prefetch_hits": cs.prefetch_hits,
        "prefetch_wasted": cs.prefetch_wasted,
        "identical_to_cache_off": identical,
    }
    gate(identical, "predictive sliding-window results differ from the "
                    "cache-off control")
    warm = waves[-1]
    gate(warm["misses"] == 0 and warm["pixels"] == 0,
         f"warm sliding-window wave after prefetch still decoded: {warm}")
    gate(cs.prefetch_issued > 0 and cs.prefetch_hits > 0,
         "prefetcher never fired on a monotone sliding scan")
    emit("cache_sliding_wave", elapsed / len(waves) * 1e6,
         f"warm_misses={warm['misses']};prefetch_hits={cs.prefetch_hits}")


# ---------------------------------------------------------- packed capacity
def bench_packed(report) -> None:
    """Capture a real ROI trace, replay it under a tight shared budget."""
    frames, dets, _ = corpus_video("sparse", 0, N_FRAMES)
    src = VideoStore(cache=CacheConfig(block_packed=True))
    src.add_video("cam0", encoder=ENC, policy=NoTilingPolicy(),
                  cost_model=shared_cost_model(), sot_len=16)
    src.ingest("cam0", frames)
    src.add_detections("cam0", {f: d for f, d in enumerate(dets)})
    try:
        for i in range(N_FRAMES // 16):
            src.scan("cam0").labels("person") \
               .frames(i * 16, (i + 1) * 16).execute()
        trace = []
        for key in list(src.tile_cache._lru):
            n, blocks = src.tile_cache.coverage(key)
            arr = src.tile_cache.get(
                key, blocks=None if blocks is None else sorted(blocks))
            trace.append((key, arr,
                          None if blocks is None else sorted(blocks)))
    finally:
        src.close()
    gate(any(b is not None for _, _, b in trace),
         "ROI workload produced no masked cache entries to replay")
    # a budget that fits only a few zero-padded canvases
    budget = 3 * max(a.nbytes for _, a, _ in trace)
    packed = TileCache(config=CacheConfig(budget_bytes=budget,
                                          block_packed=True))
    plain = TileCache(config=CacheConfig(budget_bytes=budget,
                                         block_packed=False))
    t0 = time.perf_counter()
    for key, arr, blocks in trace:
        packed.put(key, arr, blocks=blocks)
        plain.put(key, arr, blocks=blocks)
    elapsed = time.perf_counter() - t0
    identical = True
    for key, arr, blocks in trace:
        got = packed.get(key, blocks=blocks)
        if got is not None:
            identical &= bool(np.array_equal(got, arr))
    report["packed"] = {
        "trace_entries": len(trace),
        "budget_bytes": budget,
        "entries_packed": len(packed),
        "entries_padded": len(plain),
        "packed_bytes_saved": packed.stats().packed_bytes_saved,
        "identical": identical,
    }
    gate(identical, "packed entries served different pixels than stored")
    gate(len(packed) >= 2 * max(len(plain), 1),
         f"block packing fit {len(packed)} entries vs {len(plain)} "
         f"zero-padded — wanted >= 2x")
    emit("cache_packed_capacity", elapsed / max(len(trace), 1) * 1e6,
         f"entries={len(packed)}v{len(plain)};"
         f"saved={packed.stats().packed_bytes_saved}")


# ----------------------------------------------------------- lru bitforbit
class _SeedLru:
    """The pre-predictive TileCache, verbatim: the byte-for-byte reference
    that ``eviction="lru"`` must reproduce."""

    def __init__(self, budget_bytes):
        self.budget_bytes = int(budget_bytes)
        self._lru = OrderedDict()
        self.hits = self.misses = self.evictions = 0
        self.bytes = 0

    def get(self, key, n_frames=None, blocks=None):
        requested = None if blocks is None else frozenset(blocks)
        e = self._lru.get(key)
        if e is None or (n_frames is not None
                         and e[0].shape[0] < n_frames) \
                or not _covers(e[1], requested):
            self.misses += 1
            return None
        self._lru.move_to_end(key)
        self.hits += 1
        return e[0] if n_frames is None else e[0][:n_frames]

    def put(self, key, arr, blocks=None):
        if arr.nbytes > self.budget_bytes:
            return
        new_blocks = None if blocks is None else frozenset(blocks)
        old = self._lru.pop(key, None)
        if old is not None:
            if old[0].shape[0] > arr.shape[0] \
                    or not _covers(new_blocks, old[1]):
                self._lru[key] = old
                return
            self.bytes -= old[0].nbytes
        self._lru[key] = (arr, new_blocks)
        self.bytes += arr.nbytes
        while self.bytes > self.budget_bytes and self._lru:
            _, victim = self._lru.popitem(last=False)
            self.bytes -= victim[0].nbytes
            self.evictions += 1


def bench_lru_replay(report) -> None:
    rng = np.random.default_rng(0)
    shape = (8, 16, 16)
    budget = 3 * int(np.prod(shape)) * 4
    cache = TileCache(config=CacheConfig(budget_bytes=budget,
                                         eviction="lru",
                                         block_packed=False))
    seed = _SeedLru(budget)
    n_ops = 400 if QUICK else 2000
    masks = [None, [0], [1, 2], [0, 1, 2, 3]]
    t0 = time.perf_counter()
    ok = True
    for _ in range(n_ops):
        op = rng.integers(0, 3)
        key = ("v", 0, 0, int(rng.integers(0, 6)))
        depth = int(rng.choice([2, 4, 8]))
        blocks = masks[int(rng.integers(0, len(masks)))]
        if op <= 1:
            arr = rng.random((depth, 16, 16), dtype=np.float32)
            cache.put(key, arr, blocks=blocks)
            seed.put(key, arr, blocks=blocks)
        else:
            got = cache.get(key, n_frames=depth, blocks=blocks)
            want = seed.get(key, n_frames=depth, blocks=blocks)
            ok &= (got is None) == (want is None)
        st = cache.stats()
        ok &= (list(cache._lru) == list(seed._lru)
               and st.bytes_cached == seed.bytes
               and st.evictions == seed.evictions
               and (st.hits, st.misses) == (seed.hits, seed.misses))
        if not ok:
            break
    elapsed = time.perf_counter() - t0
    report["lru"] = {"ops": n_ops, "bit_for_bit": ok}
    gate(ok, 'eviction="lru" diverged from the legacy cache replay')
    emit("cache_lru_replay", elapsed / n_ops * 1e6, f"ops={n_ops};ok={ok}")


# -------------------------------------------------------------- warm latency
def bench_latency(report, pred, ctrl) -> None:
    t0 = time.perf_counter()
    for q in windows(pred):
        q.execute()
    warm_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for q in windows(ctrl):
        q.execute()
    cold_s = time.perf_counter() - t0
    report["latency"] = {
        "warm_s": warm_s, "cache_off_s": cold_s,
        "speedup": cold_s / max(warm_s, 1e-9),
    }
    # single-sample timing: soft in quick mode, hard in full runs
    gate(warm_s < cold_s,
         f"warm predictive pass ({warm_s:.3f}s) not faster than "
         f"cache-off ({cold_s:.3f}s)", hard=not QUICK)
    emit("cache_warm_pass", warm_s * 1e6,
         f"speedup={report['latency']['speedup']:.2f}x")


def main() -> None:
    report: dict = {"n_frames": N_FRAMES, "window": WINDOW, "quick": QUICK}
    frames, dets, _ = corpus_video("sparse", 0, N_FRAMES)
    pred = build_store(frames, dets, cache=PREDICTIVE)
    ctrl = build_store(frames, dets, cache=CacheConfig(budget_bytes=0))
    try:
        bench_sliding(report, pred, ctrl)
        bench_latency(report, pred, ctrl)
    finally:
        pred.close()
        ctrl.close()
    bench_packed(report)
    bench_lru_replay(report)
    pathlib.Path(OUT).write_text(json.dumps(report, indent=1))
    print(f"# wrote {OUT}: warm wave misses="
          f"{report['sliding']['waves'][-1]['misses']}, packed "
          f"{report['packed']['entries_packed']}v"
          f"{report['packed']['entries_padded']} entries, lru "
          f"bit-for-bit={report['lru']['bit_for_bit']}")


if __name__ == "__main__":
    main()
