"""Roofline report: reads the dry-run JSONL caches and emits the per-cell
three-term table (compute / memory / collective seconds, dominant term,
MODEL_FLOPS ratio, bytes/device).  See EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_rows(mesh_file: str) -> dict:
    """Last row wins per (arch, shape)."""
    path = RESULTS / mesh_file
    rows: dict[tuple, dict] = {}
    if not path.exists():
        return rows
    for line in path.read_text().splitlines():
        try:
            r = json.loads(line)
            rows[(r["arch"], r["shape"])] = r
        except json.JSONDecodeError:
            continue
    return rows


def run(mesh_file: str = "16_16.jsonl"):
    rows = load_rows(mesh_file)
    for (arch, shape), r in sorted(rows.items()):
        if r["status"] == "skipped":
            emit(f"roofline/{arch}/{shape}", 0.0, "skipped:full-attention-500k")
            continue
        if r["status"] != "ok":
            emit(f"roofline/{arch}/{shape}", 0.0, f"error:{r.get('error','?')[:60]}")
            continue
        t = r["roofline"]
        step_s = max(t["compute_s"], t["memory_s"], t["collective_s"])
        emit(
            f"roofline/{arch}/{shape}", step_s * 1e6,
            f"dominant={t['dominant']};compute={t['compute_s']:.3e};"
            f"memory={t['memory_s']:.3e};collective={t['collective_s']:.3e};"
            f"useful_ratio={t['useful_ratio']:.2f};"
            f"GB_per_dev={r['memory'].get('total_device_bytes', 0) / 1e9:.2f};"
            f"fits_hbm={r.get('fits_hbm')}")
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
