"""Fig. 11 + Table 2: cumulative decode + re-tiling time for tiling
strategies over six workloads, normalized to the untiled baseline.

Strategies: Not tiled | All objects (pre-tile) | Incremental, more |
Incremental, regret.  Workloads follow §5.3:

  W1  same object, uniform starts                     (sparse videos)
  W2  car/person 50/50, restricted to first 25%       (sparse videos)
  W3  47.5/47.5/5 car/person/traffic_light, zipf      (multiclass videos)
  W4  thirds car -> person -> car, zipf, 2x queries   (sparse videos)
  W5  dense scenes, random primary object, uniform    (dense videos)
  W6  dense scenes, single object queried             (dense videos)

Paper claims (Table 2): pre-tiling wins W1; incremental wins W2; regret wins
W3 and stays flat in W4; only regret stays ~not-tiled in W5; both incremental
approaches eventually beat not-tiled in W6 while pre-tiling loses.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import ENC, corpus_video, emit, shared_cost_model
from repro.core import (MorePolicy, NoTilingPolicy, PretileAllPolicy,
                        RegretPolicy, VideoStore)

QUICK = bool(int(os.environ.get("REPRO_QUICK", "0")))
N_FRAMES = 192 if QUICK else 384
N_QUERIES = 30 if QUICK else 80
SEEDS = (0,) if QUICK else (0, 1, 2)
WINDOW = 32  # frames per query (2 GOPs)


def _zipf_starts(rng, n, max_start):
    # Zipfian over GOP-aligned starts, biased to the beginning of the video
    ranks = np.arange(1, max_start // ENC.gop + 2)
    p = 1.0 / ranks
    p /= p.sum()
    return rng.choice(len(ranks), size=n, p=p) * ENC.gop


def make_workload(name: str, rng, n_frames: int):
    """Returns (video_kind, [(label, (start, end))])."""
    max_start = n_frames - WINDOW
    if name == "W1":
        starts = rng.integers(0, max_start + 1, N_QUERIES)
        return "sparse", [("car", (int(s), int(s) + WINDOW)) for s in starts]
    if name == "W2":
        lo = max(n_frames // 4 - WINDOW, 0)
        starts = rng.integers(0, lo + 1, N_QUERIES)
        labels = rng.choice(["car", "person"], N_QUERIES)
        return "sparse", [(l, (int(s), int(s) + WINDOW))
                          for l, s in zip(labels, starts)]
    if name == "W3":
        starts = _zipf_starts(rng, N_QUERIES, max_start)
        labels = rng.choice(["car", "person", "traffic_light"], N_QUERIES,
                            p=[0.475, 0.475, 0.05])
        return "multiclass", [(l, (int(s), int(s) + WINDOW))
                              for l, s in zip(labels, starts)]
    if name == "W4":
        n = N_QUERIES * 2
        starts = _zipf_starts(rng, n, max_start)
        labels = (["car"] * (n // 3) + ["person"] * (n // 3)
                  + ["car"] * (n - 2 * (n // 3)))
        return "sparse", [(l, (int(s), int(s) + WINDOW))
                          for l, s in zip(labels, starts)]
    if name == "W5":
        n = N_QUERIES * 2
        starts = rng.integers(0, n_frames - ENC.gop + 1, n)
        labels = rng.choice(["car", "person", "boat"], n)
        return "dense", [(l, (int(s), int(s) + ENC.gop))
                         for l, s in zip(labels, starts)]
    if name == "W6":
        n = N_QUERIES * 2
        starts = rng.integers(0, n_frames - ENC.gop + 1, n)
        return "w6", [("person", (int(s), int(s) + ENC.gop))
                      for s in starts]
    raise ValueError(name)


def make_policy(strategy: str):
    return {
        "not_tiled": NoTilingPolicy,
        "all_objects": PretileAllPolicy,
        "incremental_more": MorePolicy,
        "incremental_regret": RegretPolicy,
    }[strategy]()


def run_strategy(strategy: str, frames, dets, queries, model):
    # cache disabled: the figure compares per-layout decode cost, so repeat
    # queries must actually decode (the serving cache would zero them out).
    # inline tuning: the figure charges re-tiling to the triggering query
    # (the paper's cumulative-cost accounting), so retiles must run
    # synchronously, not on the background tuner.  ROI decode off: the
    # figure models the paper's full-tile HEVC decoder — block-restricted
    # decode would make per-query cost layout-invariant and erase the very
    # differences the figure exists to show
    store = VideoStore(tile_cache_bytes=0, tuning="inline",
                       roi_decode=False)
    store.add_video("v", encoder=ENC, policy=make_policy(strategy),
                    cost_model=model)
    store.add_detections("v", {f: d for f, d in enumerate(dets)})
    pretile_s = store.ingest("v", frames).pretile_s
    per_query = []
    first_extra = pretile_s if strategy == "all_objects" else 0.0
    for label, t_range in queries:
        res = store.scan("v").labels(label).frames(*t_range).execute()
        cost = res.stats.decode_s + res.stats.lookup_s + res.stats.retile_s
        per_query.append(cost + first_extra)
        first_extra = 0.0
    store.close()  # release the decode worker pool
    return np.array(per_query)


STRATEGIES = ("not_tiled", "all_objects", "incremental_more",
              "incremental_regret")
WORKLOADS = ("W1", "W2", "W3", "W4", "W5", "W6")


def run(workloads=WORKLOADS):
    model = shared_cost_model()
    summary = {}
    for w in workloads:
        finals: dict[str, list[float]] = {s: [] for s in STRATEGIES}
        for seed in SEEDS:
            rng = np.random.default_rng(1000 + seed)
            kind, queries = make_workload(w, rng, N_FRAMES)
            frames, dets, _ = corpus_video(kind, seed, N_FRAMES)
            base = run_strategy("not_tiled", frames, dets, queries, model)
            base_cum = base.cumsum()
            for s in STRATEGIES:
                if s == "not_tiled":
                    finals[s].append(100.0)
                    continue
                pq = run_strategy(s, frames, dets, queries, model)
                norm = 100.0 * pq.cumsum()[-1] / base_cum[-1]
                finals[s].append(norm)
        for s in STRATEGIES:
            v = np.array(finals[s])
            summary[(w, s)] = (float(np.percentile(v, 25)),
                               float(np.median(v)),
                               float(np.percentile(v, 75)))
            emit(f"fig11/{w}/{s}", 0.0,
                 f"cum_normalized={np.median(v):.0f}%"
                 f";q25={np.percentile(v,25):.0f};q75={np.percentile(v,75):.0f}")
    return summary


def main():
    run()


if __name__ == "__main__":
    main()
