"""Cross-process serving benchmark: N client PROCESSES sharing one
``VideoStoreServer`` vs N isolated per-process stores, emitting
``BENCH_server.json``.

The claim under test is the whole point of the socket front end: TASM's
shared physical state (tuned layouts, decoded-tile cache, scheduler
merging) should survive the process boundary.  Two regimes run the same
overlapping per-client scan workload:

- ``isolated`` — every client process builds its OWN store (re-ingesting
  the video) and scans it cold: the pre-server world, where external
  clients share nothing.  Per-process setup seconds (the redundant
  re-encode) are reported separately from scan seconds.
- ``served``   — the same client processes connect to one server over a
  Unix socket: scans funnel through one shared serving session, merge
  their decodes, and warm one cache.

Hard gates (CI fails if cross-client sharing regresses):
- every served client's results are bit-identical to an in-process
  ``execute()`` on the server's store (region keys AND pixels, via a
  canonical digest);
- a fresh client process repeating the workload afterwards reports zero
  cache misses and leaves the server's ``tiles_decoded_total`` unchanged —
  the "second client decodes 0 tiles" criterion;
- decode-work efficiency: the N isolated stores together decode at least
  N x the tiles the shared server decodes for the same scans
  (deterministic counters, no timing involved).

Throughput is gated HARD on end-to-end client makespan: the wall-clock a
fresh client process needs to get its results — store build + scans for
the isolated world, connect + scans for the served one — must favour the
server (``speedup_served >= 1.0``).  That is the regime the paper argues:
without a shared storage manager every analytics process re-ingests and
re-decodes for itself.  The scan-phase-only split is still reported and
soft-gated (``speedup_scan_only``): on a single-core runner it measures
GIL time-slicing between N processes rather than storage sharing — the
decode work being shared is memcpy-cheap in this synthetic codec while
reply marshalling is a real added cost — so it warns rather than fails.
Two transport gates ride along, both hard: served clients on a Unix
socket must actually negotiate shm (when the host has /dev/shm), and an
npz-transport client wave must produce byte-identical digests to the shm
wave — flipping the transport can never change results.  The marshalling
split (packing seconds, payload bytes, per-transport counts) is reported
per wave and from the server's own ``stats()``.

    PYTHONPATH=src:. python benchmarks/fig_server.py              # full
    REPRO_QUICK=1 PYTHONPATH=src:. python benchmarks/fig_server.py  # smoke
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import ENC, corpus_video, emit, gate, quick_mode

QUICK = quick_mode()
# The HARD makespan gate needs the workload in the regime the paper talks
# about — decode-dominated.  At tiny resolutions GOP decode is ~3ms and
# per-query planner overhead drowns the (N-1)x decode saving the shared
# server exists to deliver, so the corpus here is larger than the other
# figures' default (quick mode included).
N_FRAMES = 96 if QUICK else 192
HEIGHT, WIDTH = 288, 480
N_CLIENTS = 3 if QUICK else 4
SCANS_PER_CLIENT = 4 if QUICK else 8
WINDOW = 32
OUT = os.environ.get("REPRO_BENCH_OUT", "BENCH_server.json")


def workload(store):
    """The per-client scan list — IDENTICAL for every client, so the
    isolated regime re-decodes it N times while the served regime decodes
    it once and shares.  Windows overlap (stride = gop) and alternate
    labels, exercising partial tile overlap too."""
    qs = []
    for i in range(SCANS_PER_CLIENT):
        label = "car" if i % 2 == 0 else "person"
        lo = (i * ENC.gop) % (N_FRAMES - WINDOW)
        qs.append(store.scan("cam0").labels(label).frames(lo, lo + WINDOW))
    return qs


def digest(results) -> str:
    """Canonical digest over region keys + pixel bytes of a result list —
    equality means bit-identical scans without shipping arrays around."""
    h = hashlib.sha256()
    for r in results:
        for f, box, px in r.regions:
            h.update(repr((f, tuple(box), px.shape, str(px.dtype)))
                     .encode())
            h.update(np.ascontiguousarray(px).tobytes())
    return h.hexdigest()


def build_local_store(cache: bool = True):
    from benchmarks.common import shared_cost_model
    from repro.core import CacheConfig, NoTilingPolicy, VideoStore

    frames, dets, _ = corpus_video("sparse", 0, N_FRAMES, HEIGHT, WIDTH)
    store = VideoStore(
        cache=CacheConfig(budget_bytes=None if cache else 0))
    store.add_video("cam0", encoder=ENC, policy=NoTilingPolicy(),
                    cost_model=shared_cost_model())
    store.ingest("cam0", frames)
    store.add_detections("cam0", {f: d for f, d in enumerate(dets)})
    return store


# ------------------------------------------------------------- workers
def _barrier(out_path: str) -> None:
    """Align the measured scan phase across a wave's workers: signal
    ready, then wait for the parent's go-file.  Without this the first
    worker's scan window is polluted by its siblings' interpreter startup
    (or store build) time-slicing the same machine — an artifact of
    process staggering, not of the regime under test."""
    pathlib.Path(out_path + ".ready").write_text("1")
    deadline = time.time() + 300
    while not os.path.exists(out_path + ".go"):
        if time.time() > deadline:
            raise RuntimeError("wave never released the start barrier")
        time.sleep(0.005)


def isolated_worker(out_path: str) -> None:
    """One pre-server client: its own store, its own decodes."""
    t0 = time.perf_counter()
    store = build_local_store()
    setup_s = time.perf_counter() - t0
    qs = workload(store)
    _barrier(out_path)
    t0 = time.perf_counter()
    results = store.execute_many(qs)
    scan_s = time.perf_counter() - t0
    pathlib.Path(out_path).write_text(json.dumps(
        {"setup_s": setup_s, "scan_s": scan_s, "digest": digest(results),
         "tiles_decoded": store.video("cam0").store.tiles_decoded_total}))
    store.close()


def served_worker(sock: str, transport: str, out_path: str) -> None:
    """One client process of the shared server."""
    from repro.core import RemoteVideoStore

    t0 = time.perf_counter()
    cli = RemoteVideoStore(sock, transport=transport)
    connect_s = time.perf_counter() - t0
    with cli:
        qs = workload(cli)
        _barrier(out_path)
        t0 = time.perf_counter()
        results = cli.execute_many(qs)
        scan_s = time.perf_counter() - t0
        pathlib.Path(out_path).write_text(json.dumps(
            {"setup_s": connect_s, "scan_s": scan_s,
             "digest": digest(results),
             "cache_misses": sum(r.stats.cache_misses for r in results),
             "cache_hits": sum(r.stats.cache_hits for r in results),
             "transport": cli.transport,
             "marshal_s": sum(r.stats.marshal_s for r in results),
             "payload_bytes": sum(r.stats.payload_bytes
                                  for r in results)}))


def spawn(fn_name: str, *args: str) -> subprocess.Popen:
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(sys.path))
    prog = (f"import sys; from benchmarks.fig_server import {fn_name}; "
            f"{fn_name}(*sys.argv[1:])")
    return subprocess.Popen([sys.executable, "-c", prog, *args], env=env)


def run_wave(fn_name: str, outs: list[str], *extra: str) -> list[dict]:
    procs = [spawn(fn_name, *extra, out) for out in outs]
    deadline = time.time() + 900
    while not all(os.path.exists(o + ".ready") for o in outs):
        if any(p.poll() not in (None, 0) for p in procs):
            raise RuntimeError(f"a {fn_name} client died before ready")
        if time.time() > deadline:
            raise RuntimeError(f"{fn_name} clients never reached ready")
        time.sleep(0.01)
    for o in outs:  # release the start barrier for everyone at once
        pathlib.Path(o + ".go").write_text("1")
    rcs = [p.wait(timeout=900) for p in procs]
    if any(rcs):
        raise RuntimeError(f"{fn_name} clients exited {rcs}")
    return [json.loads(pathlib.Path(o).read_text()) for o in outs]


def main() -> None:
    corpus_video("sparse", 0, N_FRAMES, HEIGHT, WIDTH)  # prime the cache
    tmp = tempfile.mkdtemp(prefix="tasm_fig_server_")
    n_queries = N_CLIENTS * SCANS_PER_CLIENT
    report: dict = {"n_clients": N_CLIENTS, "n_frames": N_FRAMES,
                    "scans_per_client": SCANS_PER_CLIENT}

    # -- isolated: one store per client process ---------------------------
    iso = run_wave("isolated_worker",
                   [f"{tmp}/iso{i}.json" for i in range(N_CLIENTS)])
    report["isolated"] = {
        "scan_makespan_s": max(w["scan_s"] for w in iso),
        "e2e_makespan_s": max(w["setup_s"] + w["scan_s"] for w in iso),
        "setup_s_per_client": sum(w["setup_s"] for w in iso) / N_CLIENTS,
        "qps": n_queries / max(max(w["scan_s"] for w in iso), 1e-9)}
    gate(len({w["digest"] for w in iso}) == 1,
         "isolated clients disagree on scan results")

    # -- served: N processes, one server, one cache -----------------------
    from repro.core import VideoStoreServer

    store = build_local_store()
    sock = os.path.join(tmp, "tasm.sock")
    server = VideoStoreServer(store, path=sock, owns_store=False).start()
    try:
        tiles_cold = store.stats()["tiles_decoded_total"]
        served = run_wave("served_worker",
                          [f"{tmp}/srv{i}.json" for i in range(N_CLIENTS)],
                          sock, "auto")
        served_tiles = store.stats()["tiles_decoded_total"] - tiles_cold
        report["served"] = {
            "scan_makespan_s": max(w["scan_s"] for w in served),
            "e2e_makespan_s": max(w["setup_s"] + w["scan_s"]
                                  for w in served),
            "connect_s_per_client": sum(w["setup_s"]
                                        for w in served) / N_CLIENTS,
            "qps": n_queries / max(max(w["scan_s"] for w in served), 1e-9),
            "cache_misses": sum(w["cache_misses"] for w in served),
            "cache_hits": sum(w["cache_hits"] for w in served),
            "tiles_decoded": served_tiles,
            "transports": sorted({w["transport"] for w in served}),
            "marshal_s": sum(w["marshal_s"] for w in served),
            "payload_bytes": sum(w["payload_bytes"] for w in served)}

        # zero-copy negotiation: same-host Unix-socket clients must land
        # on the shm transport whenever the host offers shared memory
        from repro.core.shm import shm_available
        if shm_available():
            gate(all(w["transport"] == "shm" for w in served),
                 f"served clients negotiated "
                 f"{report['served']['transports']} — expected every "
                 "Unix-socket client on a /dev/shm host to ride shm")

        # decode-work efficiency, the deterministic heart of the matter:
        # N isolated stores each decode the full unique tile set; the
        # shared server decodes it ONCE for everyone
        iso_tiles = sum(w["tiles_decoded"] for w in iso)
        report["isolated"]["tiles_decoded"] = iso_tiles
        report["decode_work_ratio"] = iso_tiles / max(served_tiles, 1)
        gate(served_tiles * N_CLIENTS <= iso_tiles,
             f"shared server decoded {served_tiles} tiles; {N_CLIENTS} "
             f"isolated stores decoded {iso_tiles} — cross-client sharing "
             "is not collapsing redundant decode work")

        # bit-identity: every served client == in-process execute()
        ref = digest([q.execute() for q in workload(store)])
        report["bit_identical"] = all(w["digest"] == ref for w in served) \
            and len({w["digest"] for w in served}) == 1
        gate(report["bit_identical"],
             "served client results diverge from in-process execute()")

        # transport interop: an npz-pinned client wave must be byte-for-
        # byte identical to the shm wave — the transport can never change
        # what a query returns
        (npz_wave,) = run_wave("served_worker", [f"{tmp}/npz.json"], sock,
                               "socket")
        report["npz_client"] = {
            "transport": npz_wave["transport"],
            "marshal_s": npz_wave["marshal_s"],
            "payload_bytes": npz_wave["payload_bytes"],
            "bit_identical": npz_wave["digest"] == ref}
        gate(npz_wave["transport"] == "npz",
             f"socket-pinned client negotiated {npz_wave['transport']!r}")
        gate(npz_wave["digest"] == ref,
             "shm and npz transports produce different bytes — zero-copy "
             "path is corrupting results")

        # cross-process cache sharing: a fresh client process repeating
        # the (now warm) workload must decode NOTHING new
        tiles_before = store.stats()["tiles_decoded_total"]
        (repeat,) = run_wave("served_worker", [f"{tmp}/repeat.json"], sock,
                             "auto")
        tiles_after = store.stats()["tiles_decoded_total"]
        report["repeat_client"] = {
            "cache_misses": repeat["cache_misses"],
            "tiles_decoded": tiles_after - tiles_before,
            "scan_s": repeat["scan_s"],
            "bit_identical": repeat["digest"] == ref}
        gate(repeat["cache_misses"] == 0,
             f"repeat client had {repeat['cache_misses']} cache misses "
             "(cache not shared across processes)")
        gate(tiles_after == tiles_before,
             f"repeat client decoded {tiles_after - tiles_before} tiles")
        gate(repeat["digest"] == ref,
             "repeat client results diverge from in-process execute()")

        # marshalling split: client-observed packing cost per wave plus
        # the server's own per-transport accounting
        report["marshalling"] = {
            "served_shm": {
                "marshal_s": report["served"]["marshal_s"],
                "payload_bytes": report["served"]["payload_bytes"]},
            "served_npz": {
                "marshal_s": npz_wave["marshal_s"],
                "payload_bytes": npz_wave["payload_bytes"]},
            "server": store.stats()["marshalling"]}
    finally:
        server.stop()
        store.close()

    report["speedup_served"] = (report["isolated"]["e2e_makespan_s"]
                                / max(report["served"]["e2e_makespan_s"],
                                      1e-9))
    report["speedup_scan_only"] = (
        report["isolated"]["scan_makespan_s"]
        / max(report["served"]["scan_makespan_s"], 1e-9))
    # HARD since the zero-copy transport: end-to-end, a fresh client of
    # the shared server (connect + scan over shm) must beat a fresh
    # isolated client (store build + scan)
    gate(report["speedup_served"] >= 1.0,
         f"served e2e makespan {report['served']['e2e_makespan_s']:.3f}s "
         f"slower than isolated "
         f"{report['isolated']['e2e_makespan_s']:.3f}s")
    # soft: scan-phase-only wall on a shared machine measures process
    # time-slicing more than storage sharing (see module docstring)
    gate(report["speedup_scan_only"] >= 1.0,
         f"served scan makespan {report['served']['scan_makespan_s']:.3f}s "
         f"slower than isolated "
         f"{report['isolated']['scan_makespan_s']:.3f}s", hard=False)

    pathlib.Path(OUT).write_text(json.dumps(report, indent=1))
    emit("server_isolated", 1e6 * report["isolated"]["scan_makespan_s"]
         / n_queries, f"qps={report['isolated']['qps']:.1f}")
    emit("server_served", 1e6 * report["served"]["scan_makespan_s"]
         / n_queries,
         f"qps={report['served']['qps']:.1f};"
         f"misses={report['served']['cache_misses']}")
    emit("server_repeat_client", 1e6 * report["repeat_client"]["scan_s"]
         / SCANS_PER_CLIENT,
         f"tiles={report['repeat_client']['tiles_decoded']}")
    m = report["marshalling"]
    emit("server_marshal_shm",
         1e6 * m["served_shm"]["marshal_s"] / n_queries,
         f"bytes={int(m['served_shm']['payload_bytes'])}")
    emit("server_marshal_npz",
         1e6 * m["served_npz"]["marshal_s"] / SCANS_PER_CLIENT,
         f"bytes={int(m['served_npz']['payload_bytes'])}")
    print(f"# wrote {OUT}: {N_CLIENTS} client processes, "
          f"{report['decode_work_ratio']:.1f}x less decode work shared, "
          f"served e2e speedup {report['speedup_served']:.2f}x "
          f"(scan-only {report['speedup_scan_only']:.2f}x, "
          f"{'/'.join(report['served']['transports'])}), repeat-client "
          f"tiles {report['repeat_client']['tiles_decoded']}, "
          f"bit_identical={report['bit_identical']}")


if __name__ == "__main__":
    main()
