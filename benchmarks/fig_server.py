"""Cross-process serving benchmark: N client PROCESSES sharing one
``VideoStoreServer`` vs N isolated per-process stores, emitting
``BENCH_server.json``.

The claim under test is the whole point of the socket front end: TASM's
shared physical state (tuned layouts, decoded-tile cache, scheduler
merging) should survive the process boundary.  Two regimes run the same
overlapping per-client scan workload:

- ``isolated`` — every client process builds its OWN store (re-ingesting
  the video) and scans it cold: the pre-server world, where external
  clients share nothing.  Per-process setup seconds (the redundant
  re-encode) are reported separately from scan seconds.
- ``served``   — the same client processes connect to one server over a
  Unix socket: scans funnel through one shared serving session, merge
  their decodes, and warm one cache.

Hard gates (CI fails if cross-client sharing regresses):
- every served client's results are bit-identical to an in-process
  ``execute()`` on the server's store (region keys AND pixels, via a
  canonical digest);
- a fresh client process repeating the workload afterwards reports zero
  cache misses and leaves the server's ``tiles_decoded_total`` unchanged —
  the "second client decodes 0 tiles" criterion;
- decode-work efficiency: the N isolated stores together decode at least
  N x the tiles the shared server decodes for the same scans
  (deterministic counters, no timing involved).

Throughput (scan-phase makespan, qps) is reported, and gated softly: it
compares wall-clock of concurrent processes on one shared machine — the
single server process serializes result marshalling while the N isolated
baselines burn N cores — so it warns rather than fails (in every mode;
CI runners are noisy).

    PYTHONPATH=src:. python benchmarks/fig_server.py              # full
    REPRO_QUICK=1 PYTHONPATH=src:. python benchmarks/fig_server.py  # smoke
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import ENC, corpus_video, emit, gate, quick_mode

QUICK = quick_mode()
N_FRAMES = 96 if QUICK else 192
N_CLIENTS = 2 if QUICK else 4
SCANS_PER_CLIENT = 4 if QUICK else 8
WINDOW = 32
OUT = os.environ.get("REPRO_BENCH_OUT", "BENCH_server.json")


def workload(store):
    """The per-client scan list — IDENTICAL for every client, so the
    isolated regime re-decodes it N times while the served regime decodes
    it once and shares.  Windows overlap (stride = gop) and alternate
    labels, exercising partial tile overlap too."""
    qs = []
    for i in range(SCANS_PER_CLIENT):
        label = "car" if i % 2 == 0 else "person"
        lo = (i * ENC.gop) % (N_FRAMES - WINDOW)
        qs.append(store.scan("cam0").labels(label).frames(lo, lo + WINDOW))
    return qs


def digest(results) -> str:
    """Canonical digest over region keys + pixel bytes of a result list —
    equality means bit-identical scans without shipping arrays around."""
    h = hashlib.sha256()
    for r in results:
        for f, box, px in r.regions:
            h.update(repr((f, tuple(box), px.shape, str(px.dtype)))
                     .encode())
            h.update(np.ascontiguousarray(px).tobytes())
    return h.hexdigest()


def build_local_store(cache: bool = True):
    from benchmarks.common import shared_cost_model
    from repro.core import NoTilingPolicy, VideoStore

    frames, dets, _ = corpus_video("sparse", 0, N_FRAMES)
    store = VideoStore(tile_cache_bytes=None if cache else 0)
    store.add_video("cam0", encoder=ENC, policy=NoTilingPolicy(),
                    cost_model=shared_cost_model())
    store.ingest("cam0", frames)
    store.add_detections("cam0", {f: d for f, d in enumerate(dets)})
    return store


# ------------------------------------------------------------- workers
def isolated_worker(out_path: str) -> None:
    """One pre-server client: its own store, its own decodes."""
    t0 = time.perf_counter()
    store = build_local_store()
    setup_s = time.perf_counter() - t0
    qs = workload(store)
    t0 = time.perf_counter()
    results = [q.execute() for q in qs]
    scan_s = time.perf_counter() - t0
    pathlib.Path(out_path).write_text(json.dumps(
        {"setup_s": setup_s, "scan_s": scan_s, "digest": digest(results),
         "tiles_decoded": store.video("cam0").store.tiles_decoded_total}))
    store.close()


def served_worker(sock: str, out_path: str) -> None:
    """One client process of the shared server."""
    from repro.core import RemoteVideoStore

    with RemoteVideoStore(sock) as cli:
        qs = workload(cli)
        t0 = time.perf_counter()
        results = [q.execute() for q in qs]
        scan_s = time.perf_counter() - t0
        pathlib.Path(out_path).write_text(json.dumps(
            {"setup_s": 0.0, "scan_s": scan_s, "digest": digest(results),
             "cache_misses": sum(r.stats.cache_misses for r in results),
             "cache_hits": sum(r.stats.cache_hits for r in results)}))


def spawn(fn_name: str, *args: str) -> subprocess.Popen:
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(sys.path))
    prog = (f"import sys; from benchmarks.fig_server import {fn_name}; "
            f"{fn_name}(*sys.argv[1:])")
    return subprocess.Popen([sys.executable, "-c", prog, *args], env=env)


def run_wave(fn_name: str, outs: list[str], *extra: str) -> list[dict]:
    procs = [spawn(fn_name, *extra, out) for out in outs]
    rcs = [p.wait(timeout=900) for p in procs]
    if any(rcs):
        raise RuntimeError(f"{fn_name} clients exited {rcs}")
    return [json.loads(pathlib.Path(o).read_text()) for o in outs]


def main() -> None:
    corpus_video("sparse", 0, N_FRAMES)  # prime the cached generator
    tmp = tempfile.mkdtemp(prefix="tasm_fig_server_")
    n_queries = N_CLIENTS * SCANS_PER_CLIENT
    report: dict = {"n_clients": N_CLIENTS, "n_frames": N_FRAMES,
                    "scans_per_client": SCANS_PER_CLIENT}

    # -- isolated: one store per client process ---------------------------
    iso = run_wave("isolated_worker",
                   [f"{tmp}/iso{i}.json" for i in range(N_CLIENTS)])
    report["isolated"] = {
        "scan_makespan_s": max(w["scan_s"] for w in iso),
        "setup_s_per_client": sum(w["setup_s"] for w in iso) / N_CLIENTS,
        "qps": n_queries / max(max(w["scan_s"] for w in iso), 1e-9)}
    gate(len({w["digest"] for w in iso}) == 1,
         "isolated clients disagree on scan results")

    # -- served: N processes, one server, one cache -----------------------
    from repro.core import VideoStoreServer

    store = build_local_store()
    sock = os.path.join(tmp, "tasm.sock")
    server = VideoStoreServer(store, path=sock, owns_store=False).start()
    try:
        tiles_cold = store.stats()["tiles_decoded_total"]
        served = run_wave("served_worker",
                          [f"{tmp}/srv{i}.json" for i in range(N_CLIENTS)],
                          sock)
        served_tiles = store.stats()["tiles_decoded_total"] - tiles_cold
        report["served"] = {
            "scan_makespan_s": max(w["scan_s"] for w in served),
            "qps": n_queries / max(max(w["scan_s"] for w in served), 1e-9),
            "cache_misses": sum(w["cache_misses"] for w in served),
            "cache_hits": sum(w["cache_hits"] for w in served),
            "tiles_decoded": served_tiles}

        # decode-work efficiency, the deterministic heart of the matter:
        # N isolated stores each decode the full unique tile set; the
        # shared server decodes it ONCE for everyone
        iso_tiles = sum(w["tiles_decoded"] for w in iso)
        report["isolated"]["tiles_decoded"] = iso_tiles
        report["decode_work_ratio"] = iso_tiles / max(served_tiles, 1)
        gate(served_tiles * N_CLIENTS <= iso_tiles,
             f"shared server decoded {served_tiles} tiles; {N_CLIENTS} "
             f"isolated stores decoded {iso_tiles} — cross-client sharing "
             "is not collapsing redundant decode work")

        # bit-identity: every served client == in-process execute()
        ref = digest([q.execute() for q in workload(store)])
        report["bit_identical"] = all(w["digest"] == ref for w in served) \
            and len({w["digest"] for w in served}) == 1
        gate(report["bit_identical"],
             "served client results diverge from in-process execute()")

        # cross-process cache sharing: a fresh client process repeating
        # the (now warm) workload must decode NOTHING new
        tiles_before = store.stats()["tiles_decoded_total"]
        (repeat,) = run_wave("served_worker", [f"{tmp}/repeat.json"], sock)
        tiles_after = store.stats()["tiles_decoded_total"]
        report["repeat_client"] = {
            "cache_misses": repeat["cache_misses"],
            "tiles_decoded": tiles_after - tiles_before,
            "scan_s": repeat["scan_s"],
            "bit_identical": repeat["digest"] == ref}
        gate(repeat["cache_misses"] == 0,
             f"repeat client had {repeat['cache_misses']} cache misses "
             "(cache not shared across processes)")
        gate(tiles_after == tiles_before,
             f"repeat client decoded {tiles_after - tiles_before} tiles")
        gate(repeat["digest"] == ref,
             "repeat client results diverge from in-process execute()")
    finally:
        server.stop()
        store.close()

    report["speedup_served"] = (report["isolated"]["scan_makespan_s"]
                                / max(report["served"]["scan_makespan_s"],
                                      1e-9))
    # soft in every mode: concurrent-process wall time on a shared machine
    gate(report["speedup_served"] >= 1.0,
         f"served makespan {report['served']['scan_makespan_s']:.3f}s "
         f"slower than isolated "
         f"{report['isolated']['scan_makespan_s']:.3f}s", hard=False)

    pathlib.Path(OUT).write_text(json.dumps(report, indent=1))
    emit("server_isolated", 1e6 * report["isolated"]["scan_makespan_s"]
         / n_queries, f"qps={report['isolated']['qps']:.1f}")
    emit("server_served", 1e6 * report["served"]["scan_makespan_s"]
         / n_queries,
         f"qps={report['served']['qps']:.1f};"
         f"misses={report['served']['cache_misses']}")
    emit("server_repeat_client", 1e6 * report["repeat_client"]["scan_s"]
         / SCANS_PER_CLIENT,
         f"tiles={report['repeat_client']['tiles_decoded']}")
    print(f"# wrote {OUT}: {N_CLIENTS} client processes, "
          f"{report['decode_work_ratio']:.1f}x less decode work shared, "
          f"served speedup {report['speedup_served']:.2f}x, repeat-client "
          f"tiles {report['repeat_client']['tiles_decoded']}, "
          f"bit_identical={report['bit_identical']}")


if __name__ == "__main__":
    main()
