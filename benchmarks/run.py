"""Benchmark entry point: one section per paper table/figure plus kernel and
roofline reports.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run            # full (tee'd in CI)
    PYTHONPATH=src python -m benchmarks.run --quick    # fast smoke (= CI)
    REPRO_QUICK=1 PYTHONPATH=src python -m benchmarks.run  # same, via env
"""
from __future__ import annotations

import argparse
import os
import time
import traceback

MODULES = [
    "benchmarks.cost_model_fit",
    "benchmarks.fig6_tiling",
    "benchmarks.fig7_uniform",
    "benchmarks.fig8_granularity",
    "benchmarks.fig9_sot",
    "benchmarks.fig10_threshold",
    "benchmarks.fig11_workloads",
    "benchmarks.fig12_upfront",
    "benchmarks.fig_serving",
    "benchmarks.fig_cache",
    "benchmarks.fig_roi",
    "benchmarks.fig_tuning",
    "benchmarks.fig_server",
    "benchmarks.fig_cluster",
    "benchmarks.fig_repair",
    "benchmarks.fig_decode_kernel",
    "benchmarks.kernel_bench",
    "benchmarks.roofline_report",
]


def main() -> None:
    import importlib

    ap = argparse.ArgumentParser(description="TASM benchmark suite")
    ap.add_argument("--quick", action="store_true",
                    help="smoke sizes + soft latency gates, exactly what CI "
                         "runs (sets REPRO_QUICK=1 so local runs match CI "
                         "without exporting env vars by hand)")
    ap.add_argument("--only", metavar="SUBSTR", default=None,
                    help="run only modules whose name contains SUBSTR")
    args = ap.parse_args()
    if args.quick:
        # before any benchmark module is imported: they read the env at
        # import time to size their workloads
        os.environ["REPRO_QUICK"] = "1"
    modules = [m for m in MODULES if args.only is None or args.only in m]

    t_start = time.time()
    failures = []
    for mod_name in modules:
        print(f"# === {mod_name} ===", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
            mod.main()
        except Exception as e:  # noqa: BLE001 - benchmark isolation
            failures.append(mod_name)
            print(f"{mod_name},0.0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc()
        print(f"# {mod_name} took {time.time() - t0:.1f}s", flush=True)
    print(f"# total {time.time() - t_start:.1f}s; failures: {failures or 'none'}",
          flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
