"""Benchmark entry point: one section per paper table/figure plus kernel and
roofline reports.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run            # full (tee'd in CI)
    REPRO_QUICK=1 PYTHONPATH=src python -m benchmarks.run  # fast smoke
"""
from __future__ import annotations

import time
import traceback

MODULES = [
    "benchmarks.cost_model_fit",
    "benchmarks.fig6_tiling",
    "benchmarks.fig7_uniform",
    "benchmarks.fig8_granularity",
    "benchmarks.fig9_sot",
    "benchmarks.fig10_threshold",
    "benchmarks.fig11_workloads",
    "benchmarks.fig12_upfront",
    "benchmarks.fig_serving",
    "benchmarks.fig_roi",
    "benchmarks.fig_tuning",
    "benchmarks.kernel_bench",
    "benchmarks.roofline_report",
]


def main() -> None:
    import importlib

    t_start = time.time()
    failures = []
    for mod_name in MODULES:
        print(f"# === {mod_name} ===", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
            mod.main()
        except Exception as e:  # noqa: BLE001 - benchmark isolation
            failures.append(mod_name)
            print(f"{mod_name},0.0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc()
        print(f"# {mod_name} took {time.time() - t0:.1f}s", flush=True)
    print(f"# total {time.time() - t_start:.1f}s; failures: {failures or 'none'}",
          flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
