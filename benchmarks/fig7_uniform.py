"""Fig. 7: query-time improvement vs number of uniform tiles.

Paper claims: improvement rises 2x2 (~19%) -> 5x5 (~36%), then falls with
per-tile overhead (7x10 -> ~28%), and the IQR widens with tile count.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (boxes_for, default_corpus, emit, encode_video,
                               improvement, query_decode_seconds)
from repro.core.layout import single_tile_layout, uniform_layout

GRIDS = [(2, 2), (3, 3), (4, 4), (5, 5), (6, 8), (6, 10)]


def run(n_frames: int = 128):
    results = {g: [] for g in GRIDS}
    for name, frames, dets in default_corpus(n_frames):
        H, W = frames.shape[1:]
        omega = single_tile_layout(H, W)
        enc_o = encode_video(frames, omega)
        labels = sorted({l for d in dets for l, _ in d})
        for label in labels:
            bbf = boxes_for(dets, label, (0, n_frames))
            if len(bbf) < n_frames // 2:
                continue
            base_s, _, _ = query_decode_seconds(enc_o, omega, bbf)
            for g in GRIDS:
                lay = uniform_layout(H, W, *g)
                encs = encode_video(frames, lay)
                s, _, _ = query_decode_seconds(encs, lay, bbf)
                results[g].append(improvement(base_s, s))
    for g in GRIDS:
        vals = np.array(results[g])
        emit(f"fig7/uniform_{g[0]}x{g[1]}", 0.0,
             f"median={np.median(vals):.1f}%;iqr={np.percentile(vals,75)-np.percentile(vals,25):.1f}%")
    return results


def main():
    run()


if __name__ == "__main__":
    main()
