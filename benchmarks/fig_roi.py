"""ROI-restricted block decode benchmark, emitting ``BENCH_roi.json``.

Subframe scans should pay only for the 8x8 blocks they read.  This
benchmark measures exactly that claim: a 64x64-px ROI workload and a
full-frame workload run over three physical designs (the untiled ω layout,
a 2x4 uniform grid, and detection-aligned fine-grained layouts), each with
ROI-restricted decode ON vs OFF (the PR-3 full-tile path), on cold
per-query scans (tile cache disabled, in-memory store so decode compute —
not disk IO — dominates, matching ``fig_serving``'s methodology).

Hard gates (the CI smoke fails if they regress):
- ω / 64x64-ROI: >= 5x fewer ``pixels_decoded`` and >= 30% lower cold
  per-query latency with ROI decode on;
- every (layout, workload) cell: regions bit-identical between ROI decode
  and full-decode-then-crop.

    PYTHONPATH=src python benchmarks/fig_roi.py              # full
    REPRO_QUICK=1 PYTHONPATH=src python benchmarks/fig_roi.py  # smoke

Also prints ``name,us_per_call,derived`` CSV rows for ``benchmarks.run``.
"""
from __future__ import annotations

import json
import os
import pathlib
import time

import numpy as np

from benchmarks.common import (ENC, corpus_video, emit, gate, quick_mode,
                               shared_cost_model)
from repro.core import (CacheConfig, DecodeConfig, NoTilingPolicy,
                        VideoStore, partition, uniform_layout)

QUICK = quick_mode()
N_FRAMES = 64 if QUICK else 128
H, W = 192, 320
ROI = 64
REPEATS = 2 if QUICK else 4
OUT = os.environ.get("REPRO_BENCH_OUT", "BENCH_roi.json")

LAYOUTS = ("omega", "uniform", "detaligned")
WORKLOADS = ("roi64", "full_frame")


def roi_box(frame: int):
    """A static, 8-aligned 64x64 query box (64 codec blocks exactly)."""
    return (64, 128, 64 + ROI, 128 + ROI)


def initial_layouts(kind: str, dets):
    if kind == "omega":
        return None
    n_sots = N_FRAMES // ENC.gop
    if kind == "uniform":
        return {s: uniform_layout(H, W, 2, 4) for s in range(n_sots)}
    layouts = {}
    for s in range(n_sots):
        boxes = [b for f in range(s * ENC.gop, (s + 1) * ENC.gop)
                 for _, b in dets[f]]
        layouts[s] = partition(H, W, boxes, granularity="fine")
    return layouts


def build_store(frames, dets, kind: str, roi_on: bool) -> VideoStore:
    store = VideoStore(cache=CacheConfig(budget_bytes=0),
                       decode=DecodeConfig(roi=roi_on))
    store.add_video("cam0", encoder=ENC, policy=NoTilingPolicy(),
                    cost_model=shared_cost_model())
    store.ingest("cam0", frames, initial_layouts=initial_layouts(kind, dets))
    store.add_detections("cam0", {f: d for f, d in enumerate(dets)})
    extra = {f: [("roi", roi_box(f)), ("full", (0, 0, H, W))]
             for f in range(N_FRAMES)}
    store.add_detections("cam0", extra)
    return store


def workload(store, kind: str):
    label = "roi" if kind == "roi64" else "full"
    return [store.scan("cam0").labels(label).frames(g * ENC.gop,
                                                    (g + 1) * ENC.gop)
            for g in range(N_FRAMES // ENC.gop)]


def run_pair(on_store, off_store, wl_kind: str):
    """Cold per-query timing for both stores over the same workload,
    interleaved per repeat so allocator/cache-pressure drift hits both
    sides equally.  Returns ``{"on"|"off": (median s/query, pixels/query,
    regions)}``."""
    sides = {"on": on_store, "off": off_store}
    queries = {k: workload(s, wl_kind) for k, s in sides.items()}
    for k in sides:   # warm allocators/einsum paths once per store
        queries[k][0].execute()
    times = {k: [] for k in sides}
    regions = {k: None for k in sides}
    base = {k: sides[k].video("cam0").store.pixels_decoded_total
            for k in sides}
    for rep in range(REPEATS):
        # alternate which side goes first: run-order bias (allocator
        # warmth, CPU frequency drift) otherwise lands on one side only
        order = ("on", "off") if rep % 2 == 0 else ("off", "on")
        for k in order:
            run_regions = []
            for q in queries[k]:
                t0 = time.perf_counter()
                res = q.execute()
                times[k].append(time.perf_counter() - t0)
                run_regions.extend(res.regions)
            regions[k] = run_regions  # identical across repeats (cold)
    out = {}
    for k, s in sides.items():
        n_runs = REPEATS * len(queries[k])
        px = (s.video("cam0").store.pixels_decoded_total - base[k]) / n_runs
        out[k] = (float(np.median(times[k])), px, regions[k])
    return out


def assert_regions_equal(a, b, where: str) -> None:
    assert len(a) == len(b), (where, len(a), len(b))
    for ra, rb in zip(a, b):
        assert ra[:-1] == rb[:-1], where
        if not np.array_equal(ra[-1], rb[-1]):
            raise AssertionError(
                f"{where}: ROI decode not bit-identical to "
                f"full-decode-then-crop at frame {ra[0]}")


def main() -> None:
    frames, dets, _ = corpus_video("sparse", 0, N_FRAMES, height=H, width=W)
    report: dict = {"n_frames": N_FRAMES, "roi_px": ROI, "repeats": REPEATS,
                    "layouts": {}}

    for kind in LAYOUTS:
        cell: dict = {}
        for wl in WORKLOADS:
            on_store = build_store(frames, dets, kind, roi_on=True)
            off_store = build_store(frames, dets, kind, roi_on=False)
            pair = run_pair(on_store, off_store, wl)
            t_on, px_on, reg_on = pair["on"]
            t_off, px_off, reg_off = pair["off"]
            assert_regions_equal(reg_off, reg_on, f"{kind}/{wl}")
            on_store.close()
            off_store.close()
            cell[wl] = {
                "roi_on": {"s_per_query": t_on, "pixels_per_query": px_on},
                "roi_off": {"s_per_query": t_off, "pixels_per_query": px_off},
                "pixel_reduction": px_off / max(px_on, 1.0),
                "latency_reduction": 1.0 - t_on / max(t_off, 1e-12),
                "bit_identical": True,
            }
            emit(f"roi/{kind}/{wl}/on", 1e6 * t_on,
                 f"px={px_on / 1e6:.3f}M")
            emit(f"roi/{kind}/{wl}/off", 1e6 * t_off,
                 f"px={px_off / 1e6:.3f}M;"
                 f"px_red={cell[wl]['pixel_reduction']:.1f}x;"
                 f"lat_red={100 * cell[wl]['latency_reduction']:.0f}%")
        report["layouts"][kind] = cell

    omega = report["layouts"]["omega"]["roi64"]
    report["omega_roi64_pixel_reduction"] = omega["pixel_reduction"]
    report["omega_roi64_latency_reduction"] = omega["latency_reduction"]
    pathlib.Path(OUT).write_text(json.dumps(report, indent=1))
    print(f"# wrote {OUT}: omega/roi64 "
          f"{omega['pixel_reduction']:.1f}x fewer pixels, "
          f"{100 * omega['latency_reduction']:.0f}% lower latency")

    # acceptance gates for the ROI decode path: the pixel-count gate is a
    # deterministic correctness property (hard in every mode); the latency
    # gate compares few-sample timings, so quick mode demotes it to a
    # warning — CI-runner noise must not fail a correct build
    gate(omega["pixel_reduction"] >= 5.0,
         f"ROI pixel reduction {omega['pixel_reduction']:.2f}x < 5x")
    gate(omega["latency_reduction"] >= 0.30,
         f"ROI latency reduction {omega['latency_reduction']:.2%} < 30%",
         hard=not QUICK)


if __name__ == "__main__":
    main()
