"""Kernel micro-benchmarks.

The Pallas kernels target TPU; on this CPU container we time (a) the jnp
reference oracles (meaningful relative numbers) and (b) the kernels in
interpret mode (correctness-path cost, NOT a TPU latency).  TPU-side
roofline expectations are derived analytically in EXPERIMENTS.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.utils.timing import time_call


def run():
    key = jax.random.key(0)

    # DCT/IDCT over one 2K-ish frame worth of blocks (1920x1080 -> 32400)
    blocks = jax.random.normal(key, (32768, 8, 8), jnp.float32) * 40
    from repro.kernels.dct.ref import dct_quant_ref
    from repro.kernels.idct.ref import idct_dequant_ref

    f = jax.jit(lambda b: dct_quant_ref(b, 8, True))
    emit("kernels/dct_ref_32k_blocks", time_call(lambda: f(blocks)),
         "jnp oracle; frame-of-blocks")
    q = f(blocks)
    g = jax.jit(lambda b: idct_dequant_ref(b, 8, True))
    emit("kernels/idct_ref_32k_blocks", time_call(lambda: g(q)), "jnp oracle")

    # multi-tile batched decode: one fused dequant+IDCT+cumsum dispatch
    # over a whole merged group fetch (F frames x M gathered block columns)
    import numpy as np

    from repro.kernels.decode.ops import decode_fused_op

    rng = np.random.default_rng(0)
    for f_frames, m_cols, tag in ((16, 1024, "48-tile-ish full batch"),
                                  (16, 4096, "large merged batch")):
        qs = jnp.asarray(rng.integers(-64, 64, (f_frames, m_cols, 8, 8),
                                      dtype=np.int16))
        emit(f"kernels/decode_fused_{f_frames}x{m_cols}",
             time_call(lambda qs=qs: decode_fused_op(qs, qp=8)),
             f"jnp fused XLA path; {tag}")
    q_small = jnp.asarray(rng.integers(-64, 64, (8, 256, 8, 8),
                                       dtype=np.int16))
    emit("kernels/decode_fused_pallas_interp_8x256",
         time_call(lambda: decode_fused_op(q_small, qp=8, use_pallas=True,
                                           interpret=True)),
         "Pallas kernel, interpret mode (NOT a TPU latency)")

    # SAD: 16x16 blocks, +-8 search, one frame of blocks
    cur = jax.random.normal(key, (480, 16, 16)) * 20
    win = jax.random.normal(key, (480, 32, 32)) * 20
    from repro.kernels.sad.ref import sad_search_ref

    h = jax.jit(sad_search_ref)
    emit("kernels/sad_ref_480_blocks", time_call(lambda: h(cur, win)),
         "jnp oracle; 289 candidates/block")

    # flash attention ref vs chunked jnp at a small shape
    from repro.kernels.flash_attention.ref import attention_ref

    B, H, KV, S, D = 1, 8, 2, 1024, 64
    qq = jax.random.normal(key, (B, H, S, D), jnp.bfloat16)
    kk = jax.random.normal(key, (B, KV, S, D), jnp.bfloat16)
    vv = jax.random.normal(key, (B, KV, S, D), jnp.bfloat16)
    fa = jax.jit(lambda a, b, c: attention_ref(a, b, c, causal=True))
    emit("kernels/attention_ref_1k", time_call(lambda: fa(qq, kk, vv)),
         "jnp oracle; causal GQA")


def main():
    run()


if __name__ == "__main__":
    main()
